//! The `Simulation` builder API: CHIPSIM's public entry point.
//!
//! A co-simulation is assembled from pluggable parts — hardware, params,
//! a [`Mapper`] policy, a [`NetworkSim`] fidelity, a `ComputeBackend`,
//! optional thermal coupling, and any number of [`SimObserver`] probes —
//! then run to completion:
//!
//! ```no_run
//! use chipsim::prelude::*;
//!
//! let report = Simulation::builder()
//!     .hardware(HardwareConfig::homogeneous_mesh(6, 6))
//!     .params(SimParams { pipelined: true, ..SimParams::default() })
//!     .build()
//!     .expect("valid configuration")
//!     .run(WorkloadConfig::cnn_stream(8, 10, 0xBEEF))
//!     .expect("co-simulation");
//! println!("{}", report.summary());
//! ```
//!
//! Construction is fallible (`build()` validates the hardware and opens
//! the compute backend) so a missing PJRT artifact surfaces as an
//! actionable `Err`, never a panic.  The event loop itself is the paper's
//! Global Manager (§III): see module docs in [`crate::sim`].

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, VecDeque};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::compute::{ClassDispatchBackend, ComputeBackend, ComputeResult};
use crate::config::{
    ChipletClass, ComputeBackendKind, HardwareConfig, NocFidelity, SimParams, TopologyKind,
    WorkloadConfig,
};
use crate::dtm::DtmRuntime;
use crate::fault::{
    DowntimeTracker, FaultDims, FaultKind, FaultPlan, FaultReport, FaultTarget,
    FaultTimelineEntry, FaultToggle,
};
use crate::mapping::{MapContext, Mapper, MemoryLedger, ModelMapping, NearestNeighbor};
use crate::noc::{engine::PacketEngine, flit::FlitEngine, topology::Topology};
use crate::noc::{FlowId, FlowSpec, NetworkSim, TenantTraffic};
use crate::par::{ExecSpec, ShardedFlitEngine};
use crate::power::{PowerTracker, PowerWindow};
use crate::sim::report::{ModelOutcome, SimReport, ThermalSummary};
use crate::thermal::stepper::ThermalStepper;
use crate::trace::{BreakdownAcc, TraceConfig, TraceHandle};
use crate::workload::{ArbitrationQueue, ModelKind, ModelRequest, NeuralModel, WorkloadStream};
use crate::TimeNs;

/// Pipeline double-buffering depth: a stage may run at most this many
/// inferences ahead of its downstream consumer.
const PIPELINE_CREDITS: u32 = 2;

/// Sentinel "layer" index for ViT weight-load flows.
const WEIGHT_LAYER: usize = usize::MAX;

/// Run `$body` with the flight recorder locked as `$tr` — only when a
/// recorder is installed, and only when the crate is built with the
/// `trace` feature (default).  `--no-default-features` compiles every
/// hook site out entirely; with the feature on but no recorder
/// installed, a hook costs one `Option` branch.
#[cfg(feature = "trace")]
macro_rules! trace_hook {
    ($tracer:expr, |$tr:ident| $body:block) => {
        if let Some(__h) = $tracer.as_ref() {
            // Some hooks only feed a breakdown accumulator and leave the
            // recorder itself untouched.
            #[allow(unused_mut, unused_variables)]
            let mut $tr = __h.lock().expect("trace recorder lock");
            $body
        }
    };
}
#[cfg(not(feature = "trace"))]
macro_rules! trace_hook {
    ($tracer:expr, |$tr:ident| $body:block) => {};
}

// ------------------------------------------------------------- observers

/// Probe hooks invoked by the co-simulation loop as it progresses.
///
/// Observers are shared (`Arc<Mutex<..>>`) so the caller keeps a handle
/// and can read accumulated state after `run()` returns — and so a whole
/// `Simulation` is `Send`, which lets the fleet layer advance replica
/// boards on a worker pool.  All methods default to no-ops — implement
/// only what you need.  The built-in power tracking is itself expressible
/// as an observer: [`PowerTracker`] implements this trait, so
/// `.observer(Arc::new(Mutex::new(PowerTracker::new(n, bin))))` attaches
/// an independent power probe.
pub trait SimObserver: Send {
    /// A model was mapped onto the system at time `t`.
    fn on_model_mapped(&mut self, _id: usize, _kind: ModelKind, _t: TimeNs) {}
    /// Compute energy booked on a chiplet over `[start, start+duration)`.
    fn on_compute_energy(
        &mut self,
        _chiplet: usize,
        _start: TimeNs,
        _duration_ns: TimeNs,
        _energy_pj: f64,
    ) {
    }
    /// Instantaneous NoI energy event at a router node.
    fn on_noc_energy(&mut self, _node: usize, _t: TimeNs, _energy_pj: f64) {}
    /// A model instance finished all its inferences.
    fn on_model_finished(&mut self, _outcome: &ModelOutcome) {}
    /// A model could never fit and was dropped at time `t`.
    fn on_model_dropped(&mut self, _id: usize, _kind: ModelKind, _t: TimeNs) {}
    /// The run completed; the final report is about to be returned.
    fn on_run_complete(&mut self, _report: &SimReport) {}
}

/// A shared observer handle, as accepted by `SimulationBuilder::observer`.
pub type ObserverHandle = Arc<Mutex<dyn SimObserver>>;

/// Power tracking as a pluggable probe: mirrors exactly what the built-in
/// tracker books, so an attached `PowerTracker` observer reproduces the
/// report's dynamic-energy profile.
impl SimObserver for PowerTracker {
    fn on_compute_energy(
        &mut self,
        chiplet: usize,
        start: TimeNs,
        duration_ns: TimeNs,
        energy_pj: f64,
    ) {
        self.add_energy(chiplet, start, duration_ns, energy_pj);
    }

    fn on_noc_energy(&mut self, node: usize, t: TimeNs, energy_pj: f64) {
        self.add_event(node, t, energy_pj);
    }
}

/// Minimal event-counting observer (handy for tests and progress lines).
#[derive(Debug, Default, Clone)]
pub struct EventCounter {
    pub mapped: usize,
    pub finished: usize,
    pub dropped: usize,
    pub compute_events: usize,
    pub noc_events: usize,
    pub compute_energy_pj: f64,
}

impl SimObserver for EventCounter {
    fn on_model_mapped(&mut self, _id: usize, _kind: ModelKind, _t: TimeNs) {
        self.mapped += 1;
    }

    fn on_compute_energy(
        &mut self,
        _chiplet: usize,
        _start: TimeNs,
        _duration_ns: TimeNs,
        energy_pj: f64,
    ) {
        self.compute_events += 1;
        self.compute_energy_pj += energy_pj;
    }

    fn on_noc_energy(&mut self, _node: usize, _t: TimeNs, _energy_pj: f64) {
        self.noc_events += 1;
    }

    fn on_model_finished(&mut self, _outcome: &ModelOutcome) {
        self.finished += 1;
    }

    fn on_model_dropped(&mut self, _id: usize, _kind: ModelKind, _t: TimeNs) {
        self.dropped += 1;
    }
}

// -------------------------------------------------------- streaming core

/// Lazy, pull-based supplier of model requests for the event loop.
///
/// The batch path wraps a precomputed request list ([`BatchSource`]); the
/// serving subsystem ([`crate::serving`]) streams requests one at a time
/// from an arrival process, so an hour-long trace never materializes as a
/// `Vec`.  Implementations must yield non-decreasing `arrival_ns`.
pub trait RequestSource {
    /// Arrival time of the next request, without consuming it.
    fn peek_arrival_ns(&mut self) -> Option<TimeNs>;
    /// Consume and return the next request.
    fn next_request(&mut self) -> Option<ModelRequest>;
}

/// [`RequestSource`] over a precomputed request list (batch semantics).
pub struct BatchSource {
    requests: std::vec::IntoIter<ModelRequest>,
    peeked: Option<ModelRequest>,
}

impl BatchSource {
    pub fn new(requests: Vec<ModelRequest>) -> BatchSource {
        BatchSource { requests: requests.into_iter(), peeked: None }
    }
}

impl RequestSource for BatchSource {
    fn peek_arrival_ns(&mut self) -> Option<TimeNs> {
        if self.peeked.is_none() {
            self.peeked = self.requests.next();
        }
        self.peeked.as_ref().map(|r| r.arrival_ns)
    }

    fn next_request(&mut self) -> Option<ModelRequest> {
        self.peeked.take().or_else(|| self.requests.next())
    }
}

/// Window-draining handle passed to [`StreamSink::on_advance`].
///
/// All in-loop drains flow through it so the post-mortem thermal stepper
/// (`ThermalSpec::Native`/`Auto` on a streaming run) sees every drained
/// window instead of only the tail still live at the end of the run —
/// previously a traffic run with thermal enabled silently solved thermal
/// over the trailing window alone.
pub struct PowerPort<'a> {
    tracker: &'a mut PowerTracker,
    stepper: Option<&'a mut ThermalStepper>,
    err: &'a mut Option<anyhow::Error>,
}

impl<'a> PowerPort<'a> {
    pub fn new(
        tracker: &'a mut PowerTracker,
        stepper: Option<&'a mut ThermalStepper>,
        err: &'a mut Option<anyhow::Error>,
    ) -> PowerPort<'a> {
        PowerPort { tracker, stepper, err }
    }

    /// Drain a window from the tracker, feeding it to the attached
    /// thermal stepper first.  Stepper failures (only possible on the
    /// PJRT path) are deferred to the event loop, which fails the run.
    pub fn drain_window(&mut self, before_ns: TimeNs) -> PowerWindow {
        let window = self.tracker.drain_window(before_ns);
        if let Some(stepper) = self.stepper.as_mut() {
            let _prof_thermal = crate::prof::scope(crate::prof::Subsystem::Thermal);
            if let Err(e) = stepper.ingest(&window) {
                if self.err.is_none() {
                    *self.err = Some(e.context("in-loop thermal stepping failed"));
                }
            }
        }
        window
    }
}

/// Hooks a streaming driver installs on the event loop.
///
/// The batch path uses the no-op defaults ([`NullSink`]): outcomes
/// accumulate into the report and every power bin stays live.  The
/// sustained-traffic engine overrides them to run in constant memory:
/// outcomes flow into latency histograms, power bins drain in windows,
/// and finished instance state is retired for slot reuse.
pub trait StreamSink {
    /// A model instance finished.  Return `false` to stop the run.
    fn on_outcome(&mut self, _outcome: &ModelOutcome, _now: TimeNs) -> bool {
        true
    }

    /// Virtual time advanced to `now` (called before each event is
    /// processed).  The sink may drain power windows through the port.
    /// Return `false` to stop the run (e.g. steady state reached).
    fn on_advance(&mut self, _now: TimeNs, _power: &mut PowerPort<'_>) -> bool {
        true
    }

    /// A power window was drained *by the in-loop DTM controller* on its
    /// control cadence.  Sinks that normally drain their own windows
    /// must not drain when this feed is active (the serving engine
    /// checks `Simulation::thermal_spec().is_in_loop()` up front).
    fn on_power_window(&mut self, _window: &PowerWindow) {}

    /// A request was dropped as unmappable.  Streaming sinks count these
    /// (the report's `dropped` list is only populated when state is
    /// retained).  `tenant` is the owning tenant index (0 outside
    /// multi-tenant mixes) so per-tenant sinks can attribute the loss.
    fn on_dropped(&mut self, _id: usize, _kind: ModelKind, _tenant: usize, _now: TimeNs) {}

    /// `true` (default) keeps per-model outcomes and instance state alive
    /// for the final report; `false` retires finished instances and skips
    /// outcome accumulation (constant-memory streaming).
    fn retain_state(&self) -> bool {
        true
    }
}

/// Default no-op sink: plain batch semantics.
pub struct NullSink;

impl StreamSink for NullSink {}

// -------------------------------------------------------------- plug-ins

/// Builds a fresh network engine for a run (fidelity is injected here,
/// not matched on an enum inside the coordinator).  `Send + Sync` so a
/// `Simulation` can move between the fleet worker pool's threads.
pub type NetworkFactory = Box<dyn Fn(&Topology) -> Box<dyn NetworkSim> + Send + Sync>;

/// Thermal coupling performed by [`Simulation::run`].
///
/// `Native`/`Auto` integrate the RC network incrementally as power
/// windows drain (and over the live tail at the end), so streaming runs
/// get the *whole-horizon* trajectory, not just the undrained tail.
/// `InLoop` goes further and closes the loop: temperatures feed sensors
/// and a DVFS governor whose chosen operating points scale subsequently
/// issued compute (see [`crate::dtm`]).
#[derive(Debug, Clone, PartialEq)]
pub enum ThermalSpec {
    /// No thermal solve (default).
    Off,
    /// Native RC solver; power bins decimated by `stride_bins`.
    Native { stride_bins: usize },
    /// PJRT AOT artifact when available, native fallback otherwise.
    Auto { stride_bins: usize },
    /// Closed-loop dynamic thermal management: step thermal every
    /// `window_ns` of virtual time and let `governor` pick per-chiplet
    /// DVFS states that act back on execution.
    InLoop { window_ns: TimeNs, governor: crate::dtm::GovernorSpec },
}

impl ThermalSpec {
    pub fn is_in_loop(&self) -> bool {
        matches!(self, ThermalSpec::InLoop { .. })
    }
}

// --------------------------------------------------------------- builder

/// Staged configuration for a [`Simulation`].  Every part has a default:
/// 10×10 homogeneous mesh, default [`SimParams`], nearest-neighbour
/// mapper, packet-fidelity NoI, analytical compute, thermal off.
pub struct SimulationBuilder {
    hardware: Option<HardwareConfig>,
    params: SimParams,
    mapper: Option<Box<dyn Mapper>>,
    network: Option<NetworkFactory>,
    /// Explicit fidelity choice; wins over `params.noc_fidelity` so the
    /// builder is order-insensitive (`.network_fidelity(..)` survives a
    /// later `.params(..)`).
    fidelity: Option<NocFidelity>,
    compute: Option<Box<dyn ComputeBackend>>,
    thermal: ThermalSpec,
    observers: Vec<ObserverHandle>,
    traffic: Option<crate::serving::TrafficSpec>,
    tracer: Option<TraceHandle>,
    faults: Option<FaultPlan>,
    exec: ExecSpec,
}

impl SimulationBuilder {
    fn new() -> SimulationBuilder {
        SimulationBuilder {
            hardware: None,
            params: SimParams::default(),
            mapper: None,
            network: None,
            fidelity: None,
            compute: None,
            thermal: ThermalSpec::Off,
            observers: Vec::new(),
            traffic: None,
            tracer: None,
            faults: None,
            exec: ExecSpec::default(),
        }
    }

    /// Target hardware (chiplet grid + NoI).  Default: 10×10 type-A mesh.
    pub fn hardware(mut self, hw: HardwareConfig) -> Self {
        self.hardware = Some(hw);
        self
    }

    /// Global simulation parameters.
    pub fn params(mut self, params: SimParams) -> Self {
        self.params = params;
        self
    }

    /// Mapping policy.  Default: [`NearestNeighbor`].
    pub fn mapper(mut self, mapper: Box<dyn Mapper>) -> Self {
        self.mapper = Some(mapper);
        self
    }

    /// Custom network engine factory (overrides `params.noc_fidelity`).
    pub fn network<F>(mut self, factory: F) -> Self
    where
        F: Fn(&Topology) -> Box<dyn NetworkSim> + Send + Sync + 'static,
    {
        self.network = Some(Box::new(factory));
        self
    }

    /// Convenience: select one of the built-in NoI fidelities (wins over
    /// `params.noc_fidelity` regardless of call order; replaces any
    /// custom `network` factory).  Both fidelities scale to full
    /// serving-size runs: `Flit` costs per flit-hop actually simulated
    /// (active-set + cycle skipping), not per cycle × link.
    pub fn network_fidelity(mut self, fidelity: NocFidelity) -> Self {
        self.fidelity = Some(fidelity);
        self.network = None;
        self
    }

    /// Compute backend instance (overrides `params.compute_backend`).
    pub fn compute(mut self, backend: Box<dyn ComputeBackend>) -> Self {
        self.compute = Some(backend);
        self
    }

    /// Post-run thermal coupling.  Default: [`ThermalSpec::Off`].
    pub fn thermal(mut self, spec: ThermalSpec) -> Self {
        self.thermal = spec;
        self
    }

    /// Attach a probe; may be called repeatedly.
    pub fn observer(mut self, observer: ObserverHandle) -> Self {
        self.observers.push(observer);
        self
    }

    /// Attach a sustained-traffic specification (see [`crate::serving`]).
    /// The built simulation then serves open-loop arrival streams through
    /// [`Simulation::run_traffic`] instead of one-shot batch workloads.
    pub fn traffic(mut self, spec: crate::serving::TrafficSpec) -> Self {
        self.traffic = Some(spec);
        self
    }

    /// Attach a flight recorder built from `cfg` (request-lifecycle
    /// tracing, Perfetto export, latency breakdowns — see
    /// [`crate::trace`]).  Keep a handle for reading the trace back with
    /// [`Simulation::tracer`].
    pub fn trace(self, cfg: TraceConfig) -> Self {
        self.tracer(crate::trace::handle(crate::trace::TraceRecorder::new(cfg)))
    }

    /// Attach an existing shared recorder — e.g. one per replica board
    /// with a distinct pid base, merged later with
    /// [`crate::trace::merge_export`].
    pub fn tracer(mut self, tracer: TraceHandle) -> Self {
        self.tracer = Some(tracer);
        self
    }

    /// Attach a deterministic fault-injection plan (see [`crate::fault`]).
    /// `None` (the default) disarms injection; an armed plan whose events
    /// all resolve to nothing leaves every run byte-identical to a
    /// faultless one.
    pub fn faults(mut self, plan: Option<FaultPlan>) -> Self {
        self.faults = plan;
        self
    }

    /// How to *execute* the run (see [`crate::par`]): `threads > 1` (or
    /// `0` = all cores) swaps the flit-level NoI for the sharded parallel
    /// engine, which is byte-identical to the sequential one.  Packet
    /// fidelity and everything above the NoI are untouched — they are
    /// thread-count-invariant by construction.  A custom `network`
    /// factory wins over this, like it wins over `network_fidelity`.
    pub fn exec(mut self, exec: ExecSpec) -> Self {
        self.exec = exec;
        self
    }

    /// Validate the configuration and assemble a runnable [`Simulation`].
    ///
    /// Errors (instead of panicking) on impossible hardware — a
    /// zero-chiplet grid, I/O-only systems with nothing to compute on,
    /// out-of-range type or I/O indices — and on backends that cannot be
    /// constructed (e.g. PJRT without `make artifacts`).
    pub fn build(self) -> anyhow::Result<Simulation> {
        let hw = self.hardware.unwrap_or_else(|| HardwareConfig::homogeneous_mesh(10, 10));
        let params = self.params;

        anyhow::ensure!(
            hw.num_chiplets() > 0,
            "hardware has zero chiplets ({}x{} grid)",
            hw.rows,
            hw.cols
        );
        anyhow::ensure!(
            hw.type_of.len() == hw.num_chiplets(),
            "type_of has {} entries but the grid has {} chiplets",
            hw.type_of.len(),
            hw.num_chiplets()
        );
        for (i, &t) in hw.type_of.iter().enumerate() {
            anyhow::ensure!(
                t < hw.chiplet_types.len(),
                "chiplet {i} references type index {t}, but only {} types are defined",
                hw.chiplet_types.len()
            );
        }
        let mappable = (0..hw.num_chiplets())
            .filter(|&c| hw.chiplet_type(c).class != ChipletClass::Io)
            .count();
        anyhow::ensure!(
            mappable > 0,
            "hardware has no compute chiplets: all {} chiplets are ChipletClass::Io \
             (nothing can host a layer)",
            hw.num_chiplets()
        );
        for &io in &hw.io_chiplets {
            anyhow::ensure!(
                io < hw.num_chiplets(),
                "io_chiplets references chiplet {io}, but the grid has only {}",
                hw.num_chiplets()
            );
        }
        if let TopologyKind::Custom { links } = &hw.topology {
            for &(a, b) in links {
                anyhow::ensure!(
                    a < hw.num_chiplets() && b < hw.num_chiplets(),
                    "custom topology link ({a}, {b}) references a chiplet outside the \
                     {}-chiplet grid",
                    hw.num_chiplets()
                );
            }
        }
        anyhow::ensure!(
            params.inferences_per_model > 0,
            "inferences_per_model must be >= 1"
        );
        anyhow::ensure!(params.power_bin_ns > 0, "power_bin_ns must be > 0");

        let backend = match self.compute {
            Some(b) => b,
            None => default_backend(&params)?,
        };
        let fidelity = self.fidelity.unwrap_or(params.noc_fidelity);
        let custom_network = self.network.is_some();
        let network = match self.network {
            Some(factory) => factory,
            None => default_network_factory(fidelity, self.exec),
        };
        let topo = Topology::build(&hw);
        Ok(Simulation {
            hw,
            params,
            topo,
            mapper: self.mapper.unwrap_or_else(|| Box::new(NearestNeighbor)),
            backend,
            network,
            fidelity,
            custom_network,
            thermal: self.thermal,
            observers: self.observers,
            traffic: self.traffic,
            tenant_masks: None,
            tracer: self.tracer,
            faults: self.faults,
        })
    }
}

/// The built-in engine selection: fidelity picks the model, and a
/// parallel [`ExecSpec`] swaps the flit engine for its byte-identical
/// sharded counterpart.  Shared by `build()` and the post-build
/// [`Simulation::set_exec`] seam so both resolve identically.
fn default_network_factory(fidelity: NocFidelity, exec: ExecSpec) -> NetworkFactory {
    Box::new(move |topo: &Topology| -> Box<dyn NetworkSim> {
        match fidelity {
            NocFidelity::Packet => Box::new(PacketEngine::new(topo.clone())),
            NocFidelity::Flit if exec.is_parallel() => {
                Box::new(ShardedFlitEngine::new(topo.clone(), exec))
            }
            NocFidelity::Flit => Box::new(FlitEngine::new(topo.clone())),
        }
    })
}

/// Construct the backend selected by `params.compute_backend`, returning
/// an actionable error instead of panicking when it is unavailable.
fn default_backend(params: &SimParams) -> anyhow::Result<Box<dyn ComputeBackend>> {
    match params.compute_backend {
        ComputeBackendKind::Analytical => Ok(Box::new(ClassDispatchBackend::new())),
        ComputeBackendKind::Pjrt => {
            let backend = crate::compute::pjrt::PjrtImcBackend::open_default().map_err(|e| {
                anyhow::anyhow!(
                    "PJRT compute backend unavailable: {e}\n  expected AOT artifacts \
                     (manifest.json + imc_batch_*.hlo.txt) under {}\n  build them with \
                     `make artifacts` and compile with `--features pjrt`, or select \
                     ComputeBackendKind::Analytical",
                    crate::runtime::Runtime::default_dir().display()
                )
            })?;
            Ok(Box::new(backend))
        }
    }
}

// ------------------------------------------------------------ simulation

// (run-state structs shared with the event loop below)

#[derive(Debug, Default, Clone)]
struct LayerRuntime {
    /// Inferences with inputs ready, awaiting dispatch (credit/queue).
    ready: VecDeque<u32>,
    /// Inferences dispatched to chiplet queues.
    dispatched: u32,
    /// Inferences whose compute fully finished on this layer.
    completed: u32,
    /// Per-inference count of finished segments.
    segs_done: HashMap<u32, usize>,
    /// Earliest actual compute start per inference (for latency metrics).
    start_ns: HashMap<u32, TimeNs>,
    /// Latest compute completion per inference.
    done_ns: HashMap<u32, TimeNs>,
}

struct Instance {
    req: ModelRequest,
    model: NeuralModel,
    mapping: ModelMapping,
    results: Vec<Vec<ComputeResult>>,
    layers: Vec<LayerRuntime>,
    mapped_ns: TimeNs,
    /// Outstanding weight-load flows (ViT weight-stationary start-up).
    weight_flows: usize,
    /// inference index -> (flows outstanding into given layer).
    inflows: HashMap<(usize, u32), usize>,
    /// Comm span accounting: injection time per (dst layer, inference).
    comm_start: HashMap<(usize, u32), TimeNs>,
    comm_ns: Vec<f64>,
    inference_latency: Vec<u64>,
    inference_start: HashMap<u32, TimeNs>,
    finished: bool,
    /// Latency-breakdown accumulator: populated only when a flight
    /// recorder with breakdowns enabled is installed (boxed so the
    /// common untraced instance stays small).
    bd: Option<Box<BreakdownAcc>>,
}

impl Instance {
    /// Drop all per-run state, leaving a finished husk whose slot the
    /// streaming engine recycles — the heap held by a retired instance
    /// must not scale with how many requests the run has served.
    fn retire(&mut self) {
        self.model.layers = Vec::new();
        self.mapping.layers = Vec::new();
        self.results = Vec::new();
        self.layers = Vec::new();
        self.inflows = HashMap::new();
        self.comm_start = HashMap::new();
        self.comm_ns = Vec::new();
        self.inference_latency = Vec::new();
        self.inference_start = HashMap::new();
        self.bd = None;
    }
}

#[derive(Debug, Default)]
struct ChipletState {
    busy: bool,
    queue: VecDeque<(usize, usize, usize, u32)>, // (inst, layer, seg, inference)
    busy_ns: u64,
}

#[derive(Debug, Clone, PartialEq, Eq)]
enum Event {
    /// Re-run arbitration (after an unmap or arrival).  Arrivals
    /// themselves are not queue events: the loop pulls them lazily from
    /// the [`RequestSource`] as virtual time reaches them.
    TryMap,
    /// A segment's compute finished on its chiplet.
    ComputeDone { inst: usize, layer: usize, seg: usize, inference: u32 },
    /// A scheduled fault toggle fires (index into the armed toggle list).
    Fault(usize),
}

#[derive(Debug, Clone, PartialEq, Eq)]
struct QEntry {
    t: TimeNs,
    seq: u64,
    ev: Event,
}

impl Ord for QEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.t, self.seq).cmp(&(other.t, other.seq))
    }
}
impl PartialOrd for QEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// Schedule a queue event (monotone sequence numbers break time ties in
/// insertion order, which is what makes runs byte-identical per seed).
fn push_event(queue: &mut BinaryHeap<Reverse<QEntry>>, seq: &mut u64, t: TimeNs, ev: Event) {
    *seq += 1;
    queue.push(Reverse(QEntry { t, seq: *seq, ev }));
}

/// Live fault-injection state of one run: the armed toggle schedule, the
/// fault-aware routing view the engines currently follow, per-resource
/// outage ref-counts, and the accumulating [`FaultReport`].  Exists only
/// when the armed plan resolved to at least one toggle — absent, the run
/// is bit-for-bit the faultless run (zero-perturbation rule).
struct FaultRt {
    toggles: Vec<FaultToggle>,
    /// The pristine `Simulation::topo` with the current link mask
    /// applied.  Rebuilt from a pristine clone on every mask change, so
    /// an all-up mask restores the original routing exactly (mesh X-Y
    /// included — a BFS reroute of a healed mesh would differ).
    topo: Topology,
    /// Down ref-count per directed link: a link can be dead through its
    /// own fault and through a router fault at either end simultaneously,
    /// and must stay dead until every cause is repaired.
    link_down_cnt: Vec<u32>,
    /// Down ref-count per chiplet.
    chiplet_dead_cnt: Vec<u32>,
    downtime: DowntimeTracker,
    report: FaultReport,
}

// --------------------------------------------------------- run sessions

/// Why [`Simulation::advance_run`] returned control.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RunStatus {
    /// Everything at or before the epoch boundary has been processed.
    /// `next_event_ns` is the earliest *known* future event (queue entry
    /// or peeked arrival); `TimeNs::MAX` when only in-flight network
    /// traffic remains (its completion times are not queryable).
    Paused { next_event_ns: TimeNs },
    /// Sources drained, event queue empty, network idle: advancing
    /// further can do nothing — the run is ready for `finish_run`.
    Idle,
    /// The sink requested a stop (steady state, SLO abort) or
    /// `max_sim_time_ns` was hit.
    Stopped,
}

/// All live state of one co-simulation run between epochs.
///
/// [`Simulation::begin_run`] creates it, [`Simulation::advance_run`]
/// advances it up to a virtual-time boundary (possibly many times), and
/// [`Simulation::finish_run`] consumes it into the final [`SimReport`].
/// A monolithic run is exactly `begin` + one `advance(TimeNs::MAX)` +
/// `finish`, which is what [`Simulation::run_with_seeded`] does — the
/// epoch-bounded path exists so the fleet layer can interleave many
/// replica boards under one global clock while each keeps byte-identical
/// event ordering.  `Send`, so sessions can migrate across worker-pool
/// threads between epochs.
pub struct RunSession {
    wall_start: Instant,
    retain: bool,
    free_slots: Vec<usize>,
    stop_requested: bool,
    net: Box<dyn NetworkSim>,
    power: PowerTracker,
    stepper: Option<ThermalStepper>,
    thermal_err: Option<anyhow::Error>,
    dtm_rt: Option<DtmRuntime>,
    fault: Option<FaultRt>,
    ledger: MemoryLedger,
    arb: ArbitrationQueue,
    chiplets: Vec<ChipletState>,
    instances: Vec<Instance>,
    tenant_traffic: TenantTraffic,
    tenant_active: Vec<u64>,
    flow_of: HashMap<FlowId, (usize, usize, u32)>,
    outcomes: Vec<ModelOutcome>,
    dropped: Vec<(usize, ModelKind)>,
    queue: BinaryHeap<Reverse<QEntry>>,
    seq: u64,
    now: TimeNs,
    compute_energy: f64,
    total_capacity: u64,
    model_cache: HashMap<ModelKind, NeuralModel>,
}

impl RunSession {
    /// Virtual time the session has advanced to.
    pub fn now(&self) -> TimeNs {
        self.now
    }

    /// Requests on the board that have not finished: arbitration backlog
    /// plus mapped, in-flight instances.  The routing metric
    /// least-outstanding balances on exactly this number.
    pub fn outstanding(&self) -> usize {
        self.arb.len() + self.instances.iter().filter(|i| !i.finished).count()
    }

    /// Requests waiting in the arbitration queue (arrived, not mapped).
    pub fn queue_depth(&self) -> usize {
        self.arb.len()
    }

    /// Fraction of chiplets currently executing a segment (instantaneous
    /// utilization snapshot for autoscaling policies).
    pub fn busy_frac(&self) -> f64 {
        if self.chiplets.is_empty() {
            return 0.0;
        }
        self.chiplets.iter().filter(|c| c.busy).count() as f64 / self.chiplets.len() as f64
    }

    /// Hottest chiplet temperature the run's thermal state knows about:
    /// the in-loop DTM stepper when the run closes the loop, the
    /// post-mortem stepper under `ThermalSpec::Native`/`Auto`, `None`
    /// with thermal off.  Thermal-aware fleet routing reads this.
    pub fn hottest_c(&self) -> Option<f64> {
        if let Some(d) = &self.dtm_rt {
            return Some(d.hottest_c());
        }
        if let Some(st) = &self.stepper {
            if st.steps() > 0 {
                return Some(
                    st.chiplet_temps_c().iter().cloned().fold(f64::NEG_INFINITY, f64::max),
                );
            }
        }
        None
    }

    /// Remove and return every request still waiting in the arbitration
    /// queue (oldest first).  The fleet migration hook drains a replica
    /// that tripped its thermal-emergency predicate and re-routes the
    /// backlog; mapped, in-flight instances stay and finish locally.
    pub fn drain_backlog(&mut self) -> Vec<ModelRequest> {
        self.arb.drain_pending()
    }

    /// Remove and return every mapped, still-running request, marking its
    /// instance finished.  The fleet board-crash path extracts a dead
    /// replica's in-flight work here for retry elsewhere; the session is
    /// then discarded, so no further teardown is needed.  Sorted by
    /// (arrival, id) for a deterministic retry order.
    pub fn take_unfinished_requests(&mut self) -> Vec<ModelRequest> {
        let mut out = Vec::new();
        for inst in self.instances.iter_mut().filter(|i| !i.finished) {
            inst.finished = true;
            out.push(inst.req.clone());
        }
        out.sort_by_key(|r| (r.arrival_ns, r.id));
        out
    }
}

/// A fully assembled co-simulation: the paper's Global Manager with every
/// extension point resolved.  Build one with [`Simulation::builder`].
pub struct Simulation {
    hw: HardwareConfig,
    params: SimParams,
    topo: Topology,
    mapper: Box<dyn Mapper>,
    backend: Box<dyn ComputeBackend>,
    network: NetworkFactory,
    /// Resolved NoI fidelity (builder override or `params.noc_fidelity`),
    /// kept so [`set_exec`](Self::set_exec) can rebuild the default
    /// factory post-construction.
    fidelity: NocFidelity,
    /// Whether `network` is a user-supplied factory (which `set_exec`
    /// must not replace — custom factories win, as in the builder).
    custom_network: bool,
    thermal: ThermalSpec,
    observers: Vec<ObserverHandle>,
    traffic: Option<crate::serving::TrafficSpec>,
    /// Per-tenant placement masks (index = `ModelRequest::tenant`): when
    /// set, a request only maps onto chiplets its tenant's mask allows.
    /// Installed by the multi-tenant mix engine ([`crate::serving::mix`]).
    tenant_masks: Option<Vec<Vec<bool>>>,
    /// Optional flight recorder (see [`crate::trace`]).
    tracer: Option<TraceHandle>,
    /// Optional fault-injection plan, armed per run (see [`crate::fault`]).
    faults: Option<FaultPlan>,
}

impl Simulation {
    pub fn builder() -> SimulationBuilder {
        SimulationBuilder::new()
    }

    pub fn hardware(&self) -> &HardwareConfig {
        &self.hw
    }

    pub fn params(&self) -> &SimParams {
        &self.params
    }

    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    pub fn mapper_name(&self) -> &'static str {
        self.mapper.name()
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// The thermal coupling this simulation was built with.
    pub fn thermal_spec(&self) -> &ThermalSpec {
        &self.thermal
    }

    /// Swap the compute backend after construction (dependency injection
    /// for tests).
    pub fn set_backend(&mut self, backend: Box<dyn ComputeBackend>) {
        self.backend = backend;
    }

    /// Install per-tenant placement masks (index = request tenant).
    /// Requests of tenant `t` then only map onto chiplets where
    /// `masks[t][c]` is true; requests with a tenant index beyond the
    /// table fall back to unrestricted placement.  Compute masks with
    /// [`crate::mapping::placement::compute_placements`].
    pub fn set_tenant_masks(&mut self, masks: Vec<Vec<bool>>) {
        self.tenant_masks = Some(masks);
    }

    /// Remove any installed tenant masks (single-tenant behaviour).
    pub fn clear_tenant_masks(&mut self) {
        self.tenant_masks = None;
    }

    /// The installed per-tenant placement masks, if any.
    pub fn tenant_masks(&self) -> Option<&[Vec<bool>]> {
        self.tenant_masks.as_deref()
    }

    /// Install (or replace) a flight recorder after construction —
    /// `Scenario::build` returns a finished `Simulation`, so the CLI
    /// attaches tracing here.  Returns the handle for reading the trace
    /// back once a run completes.
    pub fn set_tracer(&mut self, tracer: TraceHandle) -> TraceHandle {
        self.tracer = Some(tracer.clone());
        tracer
    }

    /// Convenience over [`set_tracer`](Self::set_tracer): build the
    /// recorder from `cfg`.
    pub fn set_trace(&mut self, cfg: TraceConfig) -> TraceHandle {
        self.set_tracer(crate::trace::handle(crate::trace::TraceRecorder::new(cfg)))
    }

    /// The installed flight recorder, if any.
    pub fn tracer(&self) -> Option<&TraceHandle> {
        self.tracer.as_ref()
    }

    /// Remove the flight recorder (runs stop tracing).
    pub fn clear_tracer(&mut self) {
        self.tracer = None;
    }

    /// Install (or replace) a fault-injection plan after construction —
    /// `Scenario::build` returns a finished `Simulation`, so the CLI's
    /// `--faults` flag attaches plans here.  `None` disarms injection.
    pub fn set_fault_plan(&mut self, plan: Option<FaultPlan>) {
        self.faults = plan;
    }

    /// Install (or replace) the execution spec after construction — the
    /// CLI's `--threads` flag reaches scenario-built simulations here,
    /// same seam as [`set_trace`](Self::set_trace) and
    /// [`set_fault_plan`](Self::set_fault_plan).  A builder-supplied
    /// custom network factory wins: this is then a no-op, exactly as
    /// `.exec()` loses to `.network()` at build time.
    pub fn set_exec(&mut self, exec: ExecSpec) {
        if self.custom_network {
            return;
        }
        self.network = default_network_factory(self.fidelity, exec);
    }

    /// The attached fault-injection plan, if any.
    pub fn fault_plan(&self) -> Option<&FaultPlan> {
        self.faults.as_ref()
    }

    /// Run the co-simulation to completion.  Reusable: each call builds a
    /// fresh network engine and power profile, so two identical calls
    /// produce identical reports.
    pub fn run(&mut self, workload: WorkloadConfig) -> anyhow::Result<SimReport> {
        let stream = WorkloadStream::from_kinds(
            &workload.kinds,
            self.params.inferences_per_model,
            workload.injection_interval_ns,
        );
        self.run_with(&mut BatchSource::new(stream.requests), &mut NullSink)
    }

    /// Run a sustained open-loop traffic stream using the spec attached
    /// via [`SimulationBuilder::traffic`].  See [`crate::serving`].
    pub fn run_traffic(&mut self, seed: u64) -> anyhow::Result<crate::serving::TrafficReport> {
        let spec = self.traffic.clone().ok_or_else(|| {
            anyhow::anyhow!(
                "no traffic spec attached: configure one with \
                 Simulation::builder().traffic(..) or call run_traffic_with"
            )
        })?;
        self.run_traffic_with(&spec, seed)
    }

    /// Run a sustained open-loop traffic stream with an explicit spec.
    pub fn run_traffic_with(
        &mut self,
        spec: &crate::serving::TrafficSpec,
        seed: u64,
    ) -> anyhow::Result<crate::serving::TrafficReport> {
        crate::serving::engine::run_traffic(self, spec, seed)
    }

    /// Core event loop, generic over where requests come from and what
    /// happens to finished state.  `run` wires it to a precomputed batch
    /// ([`BatchSource`] + [`NullSink`]); the serving engine feeds it an
    /// arrival process and a windowing sink for constant-memory streaming.
    pub fn run_with(
        &mut self,
        source: &mut dyn RequestSource,
        sink: &mut dyn StreamSink,
    ) -> anyhow::Result<SimReport> {
        let seed = self.params.seed;
        self.run_with_seeded(source, sink, seed)
    }

    /// [`run_with`](Self::run_with) with an explicit run seed for the
    /// seed-consuming in-loop components (DTM sensor noise).  The
    /// serving engine passes its per-run traffic seed here so noise
    /// realizations vary run to run; `run_with` falls back to
    /// `params.seed`.  Exactly equivalent to [`begin_run`](Self::begin_run)
    /// + one unbounded [`advance_run`](Self::advance_run) +
    /// [`finish_run`](Self::finish_run).
    pub fn run_with_seeded(
        &mut self,
        source: &mut dyn RequestSource,
        sink: &mut dyn StreamSink,
        run_seed: u64,
    ) -> anyhow::Result<SimReport> {
        let mut session = self.begin_run(run_seed, sink.retain_state())?;
        self.advance_run(&mut session, source, sink, TimeNs::MAX)?;
        self.finish_run(session, sink)
    }

    /// Allocate the live state of one run: network engine, power tracker,
    /// thermal stepper / DTM controller, arbitration queue, event queue.
    /// `retain` mirrors [`StreamSink::retain_state`] — batch sinks keep
    /// outcomes and power bins, streaming sinks drain them.  Drive the
    /// returned session with [`advance_run`](Self::advance_run) and close
    /// it with [`finish_run`](Self::finish_run).
    pub fn begin_run(&mut self, run_seed: u64, retain: bool) -> anyhow::Result<RunSession> {
        let wall_start = Instant::now();
        // warn_once! deduplicates per run.
        crate::util::logging::reset_warn_once();
        let mut net: Box<dyn NetworkSim> = (self.network)(&self.topo);
        // Hop energy is only ever consumed at power-bin granularity, so
        // let the engine coalesce its event stream to the tracker's bin
        // (one entry per (node, bin) instead of one per flit/packet hop).
        net.set_energy_bin_ns(self.params.power_bin_ns);
        let mut power = PowerTracker::new(self.hw.num_chiplets(), self.params.power_bin_ns);
        // Thermal coupling: Native/Auto attach an incremental stepper to
        // the sink's drain path (post-mortem trajectory over the whole
        // horizon); InLoop instead owns a full DTM controller that drains
        // on its control cadence and feeds frequency/voltage back.
        let stepper: Option<ThermalStepper> = match &self.thermal {
            ThermalSpec::Off | ThermalSpec::InLoop { .. } => None,
            ThermalSpec::Native { stride_bins } => Some(ThermalStepper::new(
                &self.hw,
                self.params.power_bin_ns,
                (*stride_bins).max(1),
                false,
            )?),
            ThermalSpec::Auto { stride_bins } => Some(ThermalStepper::new(
                &self.hw,
                self.params.power_bin_ns,
                (*stride_bins).max(1),
                true,
            )?),
        };
        let dtm_rt: Option<DtmRuntime> = match &self.thermal {
            ThermalSpec::InLoop { window_ns, governor } => Some(DtmRuntime::new(
                &self.hw,
                self.params.power_bin_ns,
                *window_ns,
                governor,
                run_seed,
                // Streaming sinks retire state and expect drained
                // windows; batch runs peek so the report's power trace
                // stays intact.
                !retain,
            )?),
            _ => None,
        };
        for c in 0..self.hw.num_chiplets() {
            power.set_baseline_mw(
                c,
                self.hw.chiplet_type(c).idle_mw + self.hw.link.router_static_mw,
            );
        }
        let ledger = MemoryLedger::new(&self.hw);
        let total_capacity = ledger.total_free();
        // Arm the fault plan against this run's dimensions.  The runtime
        // exists only when the armed plan resolves to at least one toggle:
        // an armed-but-empty plan must perturb nothing, so its run stays
        // fingerprint-identical to a faultless one.
        let fault: Option<FaultRt> = match &self.faults {
            Some(plan) if !plan.is_empty() => {
                let toggles = plan.arm(&FaultDims {
                    links: self.topo.links.len(),
                    nodes: self.topo.num_nodes,
                    chiplets: self.hw.num_chiplets(),
                })?;
                if toggles.is_empty() {
                    None
                } else {
                    Some(FaultRt {
                        toggles,
                        topo: self.topo.clone(),
                        link_down_cnt: vec![0; self.topo.links.len()],
                        chiplet_dead_cnt: vec![0; self.hw.num_chiplets()],
                        downtime: DowntimeTracker::default(),
                        report: FaultReport::default(),
                    })
                }
            }
            _ => None,
        };
        // Arm the flight recorder: fresh buffers (byte-identical reuse),
        // track metadata, and per-link tracing in the network engine.
        // Compiled out without the `trace` feature.
        #[cfg(feature = "trace")]
        if let Some(h) = &self.tracer {
            let mut tr = h.lock().expect("trace recorder lock");
            use crate::trace::{TraceCategories as TC, PID_CHIPLET, PID_GAUGE, PID_NOI, PID_REQUEST};
            tr.reset();
            tr.name_process(PID_CHIPLET, "chiplets");
            for c in 0..self.hw.num_chiplets() {
                tr.name_thread(PID_CHIPLET, c as u32, &format!("chiplet {c}"));
            }
            if tr.enabled(TC::NOI) {
                net.set_link_trace(true);
                tr.name_process(PID_NOI, "NoI links");
                for (l, link) in self.topo.links.iter().enumerate() {
                    tr.name_thread(PID_NOI, l as u32, &format!("link {}->{}", link.src, link.dst));
                }
            }
            tr.name_process(PID_REQUEST, "requests");
            tr.name_process(PID_GAUGE, "gauges");
            if fault.is_some() {
                tr.name_process(crate::trace::PID_FAULT, "faults");
            }
        }
        let mut session = RunSession {
            wall_start,
            retain,
            free_slots: Vec::new(),
            stop_requested: false,
            net,
            power,
            stepper,
            thermal_err: None,
            dtm_rt,
            fault,
            ledger,
            arb: ArbitrationQueue::new(self.params.age_threshold_ns),
            chiplets: (0..self.hw.num_chiplets()).map(|_| ChipletState::default()).collect(),
            instances: Vec::new(),
            // Multi-tenant accounting: NoI traffic attributed per tenant,
            // and how many instances each tenant has resident (the drop
            // probe only examines a tenant's queue while it has nothing
            // mapped).  Sized up front from the mask table so "tenant
            // never mapped anything yet" reads as an explicit zero, not a
            // missing slot.
            tenant_traffic: TenantTraffic::new(),
            tenant_active: vec![
                0;
                self.tenant_masks.as_ref().map(|m| m.len()).unwrap_or(1).max(1)
            ],
            flow_of: HashMap::new(),
            outcomes: Vec::new(),
            dropped: Vec::new(),
            queue: BinaryHeap::new(),
            seq: 0,
            now: 0,
            compute_energy: 0.0,
            total_capacity,
            model_cache: HashMap::new(),
        };
        // Fault instants ride the ordinary event queue, so they
        // interleave deterministically with arrivals and completions.
        if let Some(f) = &session.fault {
            for (i, tog) in f.toggles.iter().enumerate() {
                push_event(&mut session.queue, &mut session.seq, tog.at_ns, Event::Fault(i));
            }
        }
        Ok(session)
    }

    /// Advance the session, processing every arrival and queue event with
    /// `t <= until` (an absolute virtual time; `TimeNs::MAX` = run to
    /// completion).  Bounding the epoch never changes a replica's own
    /// event order, so an epoch-chopped run is byte-identical to an
    /// unbounded one — the fleet dispatcher relies on this when it
    /// advances replicas in lockstep between global clock barriers.
    pub fn advance_run(
        &mut self,
        s: &mut RunSession,
        source: &mut dyn RequestSource,
        sink: &mut dyn StreamSink,
        until: TimeNs,
    ) -> anyhow::Result<RunStatus> {
        let RunSession {
            retain,
            free_slots,
            stop_requested,
            net,
            power,
            stepper,
            thermal_err,
            dtm_rt,
            fault,
            ledger,
            arb,
            chiplets,
            instances,
            tenant_traffic,
            tenant_active,
            flow_of,
            outcomes,
            dropped,
            queue,
            seq,
            now,
            compute_energy,
            total_capacity,
            model_cache,
            ..
        } = s;

        // One shared-handle clone per epoch, not per event.
        #[cfg(feature = "trace")]
        let tracer = self.tracer.clone();

        // Self-profiling: one event-loop scope per epoch; the nested
        // subsystem scopes below subtract out, leaving dispatch
        // overhead as this scope's self time.  Costs one relaxed
        // atomic load when profiling is disabled.
        let _prof_loop = crate::prof::scope(crate::prof::Subsystem::EventLoop);

        macro_rules! notify {
            ($($call:tt)*) => {
                for ob in &self.observers {
                    ob.lock().expect("observer lock").$($call)*;
                }
            };
        }

        macro_rules! start_chiplet_if_idle {
            ($c:expr, $t:expr) => {{
                let _prof_issue = crate::prof::scope(crate::prof::Subsystem::ComputeIssue);
                let cid = $c;
                // A killed chiplet issues nothing until repaired (its
                // queue is purged when its owners abort, but the guard
                // also covers the window inside one event's handling).
                let dead = fault
                    .as_ref()
                    .is_some_and(|f| f.chiplet_dead_cnt.get(cid).is_some_and(|&c| c > 0));
                if !chiplets[cid].busy && !dead {
                    if let Some((inst, layer, seg, inference)) = chiplets[cid].queue.pop_front() {
                        let r = instances[inst].results[layer][seg];
                        // DVFS feedback: the chiplet's current operating
                        // point scales work *issued now*; in-flight
                        // segments finish at their issued rate.
                        let (lat_scale, energy_scale) = match dtm_rt.as_ref() {
                            Some(d) => (d.latency_factor(cid), d.energy_factor(cid)),
                            None => (1.0, 1.0),
                        };
                        let lat = (r.latency_ns * lat_scale).round().max(1.0) as TimeNs;
                        let energy = r.energy_pj * energy_scale;
                        chiplets[cid].busy = true;
                        chiplets[cid].busy_ns += lat;
                        power.add_energy(cid, $t, lat, energy);
                        notify!(on_compute_energy(cid, $t, lat, energy));
                        *compute_energy += energy;
                        let lr = &mut instances[inst].layers[layer];
                        lr.start_ns.entry(inference).or_insert($t);
                        if layer == 0 {
                            instances[inst].inference_start.entry(inference).or_insert($t);
                        }
                        push_event(
                            queue,
                            seq,
                            $t + lat,
                            Event::ComputeDone { inst, layer, seg, inference },
                        );
                        trace_hook!(tracer, |tr| {
                            use crate::trace::TraceCategories as TC;
                            if tr.enabled(TC::COMPUTE) {
                                tr.span(
                                    TC::COMPUTE,
                                    crate::trace::PID_CHIPLET,
                                    cid as u32,
                                    format!("L{layer} {}", instances[inst].req.kind.name()),
                                    $t,
                                    lat,
                                    vec![
                                        ("req", (instances[inst].req.id as u64).into()),
                                        ("seg", (seg as u64).into()),
                                        ("inference", (inference as u64).into()),
                                        ("dvfs_latency_factor", lat_scale.into()),
                                    ],
                                );
                            }
                            if let Some(bd) = instances[inst].bd.as_deref_mut() {
                                bd.on_compute($t, lat, r.latency_ns.round().max(1.0) as TimeNs);
                            }
                        });
                    }
                }
            }};
        }

        macro_rules! dispatch_ready {
            ($inst:expr, $layer:expr, $t:expr) => {{
                let inst = $inst;
                let layer = $layer;
                loop {
                    let can = {
                        let me = &instances[inst];
                        let lr = &me.layers[layer];
                        if lr.ready.is_empty() {
                            false
                        } else if !self.params.pipelined {
                            true // sequential execution: no overlap possible
                        } else if layer + 1 >= me.layers.len() {
                            true
                        } else {
                            // Double-buffering credit vs downstream stage.
                            lr.dispatched < me.layers[layer + 1].completed + PIPELINE_CREDITS
                        }
                    };
                    if !can {
                        break;
                    }
                    let inference = instances[inst].layers[layer].ready.pop_front().unwrap();
                    instances[inst].layers[layer].dispatched += 1;
                    let nsegs = instances[inst].mapping.layers[layer].len();
                    for s in 0..nsegs {
                        let cid = instances[inst].mapping.layers[layer][s].chiplet;
                        chiplets[cid].queue.push_back((inst, layer, s, inference));
                        start_chiplet_if_idle!(cid, $t);
                    }
                }
            }};
        }

        // The routing view injections must consult: the fault-masked
        // topology while a fault runtime exists, the pristine one
        // otherwise.  A macro (not a binding) so each use borrows only
        // for the expression — `fault` stays mutably borrowable between.
        macro_rules! net_topo {
            () => {
                fault.as_ref().map(|f| &f.topo).unwrap_or(&self.topo)
            };
        }

        // Fault-path teardown: a request whose in-flight state was hit by
        // a fault (killed chiplet, partitioned flow destination) aborts.
        // Its resources free immediately, its queued segments are purged,
        // its remaining events become no-ops via the `finished` guards,
        // and it counts as dropped — request conservation (offered ==
        // completed + dropped + still-queued) holds.  The slot is NOT
        // retired: stale ComputeDone events still index the mapping.
        macro_rules! abort_instance {
            ($inst:expr, $t:expr) => {{
                let inst = $inst;
                if !instances[inst].finished {
                    instances[inst].finished = true;
                    ledger.release_mapping(&instances[inst].mapping);
                    if let Some(active) = tenant_active.get_mut(instances[inst].req.tenant) {
                        *active = active.saturating_sub(1);
                    }
                    for c in chiplets.iter_mut() {
                        c.queue.retain(|&(i, _, _, _)| i != inst);
                    }
                    flow_of.retain(|_, v| v.0 != inst);
                    if let Some(f) = fault.as_mut() {
                        f.report.aborts += 1;
                    }
                    let (id, kind, tenant) = {
                        let r = &instances[inst].req;
                        (r.id, r.kind, r.tenant)
                    };
                    notify!(on_model_dropped(id, kind, $t));
                    sink.on_dropped(id, kind, tenant, $t);
                    trace_hook!(tracer, |tr| {
                        tr.async_end(
                            crate::trace::TraceCategories::REQUEST,
                            crate::trace::PID_REQUEST,
                            tenant as u32,
                            "request",
                            id as u64,
                            $t,
                            vec![("state", "aborted-by-fault".into())],
                        );
                    });
                    if *retain {
                        dropped.push((id, kind));
                    }
                    push_event(queue, seq, $t, Event::TryMap);
                }
            }};
        }

        // Models are immutable per kind: build each once and clone cheaply
        // (arbitration probes used to rebuild the full layer table per
        // attempt — a measurable share of wall time, see EXPERIMENTS §Perf).
        // The cache lives in the session so epoch-bounded runs keep it warm.
        let mut model_of = |kind: ModelKind| -> NeuralModel {
            model_cache.entry(kind).or_insert_with(|| NeuralModel::build(kind)).clone()
        };

        macro_rules! try_map_models {
            ($t:expr) => {{
                let _prof_map = crate::prof::scope(crate::prof::Subsystem::Mapping);
                // Thermal-aware extension: rank chiplets by accumulated
                // dissipation (temperature proxy) when enabled.
                let heat: Option<Vec<f64>> = if self.params.thermal_aware_hops > 0.0 {
                    Some(
                        (0..self.hw.num_chiplets())
                            .map(|c| power.dynamic_energy_pj(c))
                            .collect(),
                    )
                } else {
                    None
                };
                // Fault-aware placement: dead chiplets are excluded from
                // every mapping attempt.  Computed once per arbitration
                // pass; `None` while nothing is down, so the faultless
                // path stays untouched.
                let alive: Option<Vec<bool>> = fault.as_ref().and_then(|f| {
                    if f.chiplet_dead_cnt.iter().all(|&c| c == 0) {
                        None
                    } else {
                        Some(f.chiplet_dead_cnt.iter().map(|&c| c == 0).collect())
                    }
                });
                let mut mask_buf: Vec<bool> = Vec::new();
                loop {
                    // Probe and commit in one pass: the mapper journals
                    // its allocations on the live ledger and rolls back on
                    // failure, so a successful probe *is* the mapping — no
                    // speculative ledger clone, no second placement pass.
                    // The context is per-request: placement masks confine
                    // each request to its owning tenant's chiplets.
                    let mut probed: Option<ModelMapping> = None;
                    let taken = arb.take_next_mappable($t, |req| {
                        let model = model_of(req.kind);
                        let ctx = MapContext {
                            hw: &self.hw,
                            topo: net_topo!(),
                            heat: heat.as_deref(),
                            heat_weight_hops: self.params.thermal_aware_hops,
                            allowed: combine_allowed(
                                mask_of(&self.tenant_masks, req.tenant),
                                alive.as_deref(),
                                &mut mask_buf,
                            ),
                        };
                        crate::prof::count(crate::prof::Counter::MappingAttempts, 1);
                        probed = self.mapper.try_map(&ctx, &model, &mut ledger);
                        probed.is_some()
                    });
                    let Some(req) = taken else { break };
                    let model = model_of(req.kind);
                    let mapping = probed.take().expect("probe said it fits");
                    // Batched compute evaluation (one backend call per model).
                    let mut items = Vec::new();
                    for layer in mapping.layers.iter() {
                        for seg in layer {
                            items.push((self.hw.chiplet_type(seg.chiplet), seg.work));
                        }
                    }
                    let flat = self.backend.evaluate_batch(&items);
                    let mut results = Vec::with_capacity(mapping.layers.len());
                    let mut k = 0;
                    for layer in &mapping.layers {
                        let n = layer.len();
                        results.push(flat[k..k + n].to_vec());
                        k += n;
                    }
                    let nlayers = mapping.layers.len();
                    // Reuse a retired slot when streaming; append otherwise.
                    let inst_id = free_slots.pop().unwrap_or(instances.len());
                    notify!(on_model_mapped(req.id, req.kind, $t));
                    let inferences = req.inferences;
                    let tenant = req.tenant;
                    if tenant >= tenant_active.len() {
                        tenant_active.resize(tenant + 1, 0);
                    }
                    tenant_active[tenant] += 1;
                    let mut inst = Instance {
                        req,
                        model,
                        mapping,
                        results,
                        layers: vec![LayerRuntime::default(); nlayers],
                        mapped_ns: $t,
                        weight_flows: 0,
                        inflows: HashMap::new(),
                        comm_start: HashMap::new(),
                        comm_ns: vec![0.0; inferences as usize],
                        inference_latency: Vec::new(),
                        inference_start: HashMap::new(),
                        finished: false,
                        bd: None,
                    };
                    trace_hook!(tracer, |tr| {
                        use crate::trace::TraceCategories as TC;
                        let r = &inst.req;
                        tr.async_instant(
                            TC::REQUEST,
                            crate::trace::PID_REQUEST,
                            tenant as u32,
                            "request",
                            r.id as u64,
                            $t,
                            vec![("state", "mapped".into()), ("kind", r.kind.name().into())],
                        );
                        if tr.breakdown_enabled() {
                            let mut bd = Box::new(BreakdownAcc::new(r.arrival_ns));
                            bd.on_mapped($t);
                            inst.bd = Some(bd);
                        }
                    });
                    // ViT-style weight-stationary start-up: stream each
                    // segment's weights from the nearest I/O chiplet.
                    if !self.hw.io_chiplets.is_empty() {
                        let mut flows = Vec::new();
                        for layer in &inst.mapping.layers {
                            for seg in layer {
                                // Unreachable I/O chiplets rank last
                                // (`None` would otherwise sort *first*
                                // under `Option`'s ordering and pick a
                                // partitioned source).
                                let io = *self
                                    .hw
                                    .io_chiplets
                                    .iter()
                                    .min_by_key(|&&io| {
                                        net_topo!().hops(io, seg.chiplet).unwrap_or(usize::MAX)
                                    })
                                    .unwrap();
                                flows.push(FlowSpec {
                                    src: io,
                                    dst: seg.chiplet,
                                    bytes: seg.mem_bytes,
                                });
                            }
                        }
                        inst.weight_flows = flows.len();
                        trace_hook!(tracer, |tr| {
                            if let Some(bd) = inst.bd.as_deref_mut() {
                                for f in &flows {
                                    let ideal = ideal_flow_ns(&self.topo, f.src, f.dst, f.bytes);
                                    bd.on_flows(WEIGHT_LAYER, 0, $t, ideal);
                                }
                            }
                        });
                        if inst_id == instances.len() {
                            instances.push(inst);
                        } else {
                            instances[inst_id] = inst;
                        }
                        for f in flows {
                            if !net_topo!().reachable(f.src, f.dst) {
                                // The weight source is partitioned away:
                                // the request can never start.
                                if let Some(fr) = fault.as_mut() {
                                    fr.report.flow_fails += 1;
                                }
                                abort_instance!(inst_id, $t);
                                break;
                            }
                            let hops = net_topo!().hops(f.src, f.dst).unwrap_or(0);
                            tenant_traffic.add_flow(tenant, f.bytes, hops);
                            let id = net.inject(f, $t);
                            flow_of.insert(id, (inst_id, WEIGHT_LAYER, 0));
                        }
                    } else {
                        inst.layers[0].ready.push_back(0);
                        if inst_id == instances.len() {
                            instances.push(inst);
                        } else {
                            instances[inst_id] = inst;
                        }
                        dispatch_ready!(inst_id, 0, $t);
                    }
                }
                // Requests that can never fit even on an *empty* system —
                // or, under placement masks, an empty tenant partition —
                // are dropped (and reported) instead of deadlocking the
                // queue.  A tenant's queue is only probed while it has
                // nothing mapped: a busy tenant's unmappable request may
                // simply be waiting for its own instances to unmap, which
                // is the normal backlog case, not a dead one.  The guard
                // keeps the whole walk off the hot path: a saturated run
                // (every tenant busy) skips it with one vector scan
                // instead of touching the backlog per event.  Within one
                // pass, a tenant whose oldest pending request turns out
                // to fit an empty placement is memoized and its younger
                // requests skipped — an idle tenant queueing behind a
                // co-tenant's memory pays one empty-fit probe per event,
                // not one per backlog entry.
                let mut dropped_any = false;
                let mut fits_empty: Vec<usize> = Vec::new();
                while !arb.is_empty() && tenant_active.iter().any(|&a| a == 0) {
                    let taken = arb.take_next_mappable($t, |req| {
                        if tenant_active.get(req.tenant).copied().unwrap_or(0) > 0
                            || fits_empty.contains(&req.tenant)
                        {
                            return false;
                        }
                        let model = model_of(req.kind);
                        // Deliberately NOT masked by dead chiplets: the
                        // drop verdict is "can never fit", and a chiplet
                        // down right now may be repaired later — such
                        // requests queue for the repair instead.
                        let probe_ctx = MapContext {
                            hw: &self.hw,
                            topo: &self.topo,
                            heat: None,
                            heat_weight_hops: 0.0,
                            allowed: mask_of(&self.tenant_masks, req.tenant),
                        };
                        let mut probe = MemoryLedger::new(&self.hw);
                        // Taking the request == sentencing it to drop.
                        if self.mapper.try_map(&probe_ctx, &model, &mut probe).is_some() {
                            fits_empty.push(req.tenant);
                            return false;
                        }
                        true
                    });
                    let Some(req) = taken else { break };
                    // Per-run dedup: a saturated run can drop the same
                    // oversized kind thousands of times; the message is
                    // id-free so one line covers the whole (kind, tenant)
                    // class and the request track records each drop.
                    crate::warn_once!(
                        "dropping {} requests of tenant {}: {} bytes cannot fit an empty \
                         placement (system capacity {})",
                        req.kind.name(),
                        req.tenant,
                        model_of(req.kind).total_weight_bytes(),
                        total_capacity
                    );
                    notify!(on_model_dropped(req.id, req.kind, $t));
                    sink.on_dropped(req.id, req.kind, req.tenant, $t);
                    trace_hook!(tracer, |tr| {
                        tr.async_end(
                            crate::trace::TraceCategories::REQUEST,
                            crate::trace::PID_REQUEST,
                            req.tenant as u32,
                            "request",
                            req.id as u64,
                            $t,
                            vec![("state", "dropped".into())],
                        );
                    });
                    if *retain {
                        dropped.push((req.id, req.kind));
                    }
                    dropped_any = true;
                }
                if dropped_any {
                    // A dropped request may have been the over-age blocker
                    // pinning younger, mappable requests in the queue:
                    // re-run arbitration once the event is processed.
                    push_event(queue, seq, $t, Event::TryMap);
                }
            }};
        }

        macro_rules! emit_layer_flows {
            ($inst:expr, $layer:expr, $inference:expr, $t:expr) => {{
                let inst = $inst;
                let layer = $layer;
                let inference = $inference;
                let (flows, expected, tenant) = {
                    let me = &instances[inst];
                    let out_bytes = me.model.layers[layer].out_bytes;
                    let srcs = &me.mapping.layers[layer];
                    let dsts = &me.mapping.layers[layer + 1];
                    let mut flows = Vec::new();
                    for s in srcs {
                        // Each destination segment needs the full activation
                        // tensor; each source produced `frac` of it.
                        let bytes = ((out_bytes as f64) * s.frac).ceil().max(1.0) as u64;
                        for d in dsts {
                            flows.push(FlowSpec { src: s.chiplet, dst: d.chiplet, bytes });
                        }
                    }
                    let n = flows.len();
                    (flows, n, me.req.tenant)
                };
                instances[inst].inflows.insert((layer + 1, inference), expected);
                instances[inst].comm_start.insert((layer + 1, inference), $t);
                trace_hook!(tracer, |tr| {
                    use crate::trace::TraceCategories as TC;
                    if tr.enabled(TC::NOI) {
                        tr.instant(
                            TC::NOI,
                            crate::trace::PID_REQUEST,
                            tenant as u32,
                            format!("flows L{layer}->L{}", layer + 1),
                            $t,
                            vec![
                                ("req", (instances[inst].req.id as u64).into()),
                                ("flows", (expected as u64).into()),
                                ("inference", (inference as u64).into()),
                            ],
                        );
                    }
                    if let Some(bd) = instances[inst].bd.as_deref_mut() {
                        for f in &flows {
                            let ideal = ideal_flow_ns(&self.topo, f.src, f.dst, f.bytes);
                            bd.on_flows(layer + 1, inference, $t, ideal);
                        }
                    }
                });
                for f in flows {
                    if !net_topo!().reachable(f.src, f.dst) {
                        // The destination segment is partitioned away
                        // mid-run: this inference can never complete.
                        if let Some(fr) = fault.as_mut() {
                            fr.report.flow_fails += 1;
                        }
                        abort_instance!(inst, $t);
                        break;
                    }
                    let hops = net_topo!().hops(f.src, f.dst).unwrap_or(0);
                    tenant_traffic.add_flow(tenant, f.bytes, hops);
                    let id = net.inject(f, $t);
                    flow_of.insert(id, (inst, layer + 1, inference));
                }
            }};
        }

        macro_rules! finish_instance {
            ($inst:expr, $t:expr) => {{
                let inst = $inst;
                crate::prof::count(crate::prof::Counter::RequestsCompleted, 1);
                if let Some(f) = fault.as_mut() {
                    if f.downtime.any_down() {
                        f.report.goodput_under_fault += 1;
                    }
                }
                instances[inst].finished = true;
                ledger.release_mapping(&instances[inst].mapping);
                if let Some(active) = tenant_active.get_mut(instances[inst].req.tenant) {
                    *active = active.saturating_sub(1);
                }
                // Finalize the breakdown (always `None` when untraced).
                let bd_final = instances[inst].bd.take().map(|b| b.finish($t));
                let outcome = {
                    let me = &instances[inst];
                    ModelOutcome {
                        id: me.req.id,
                        kind: me.req.kind,
                        tenant: me.req.tenant,
                        arrival_ns: me.req.arrival_ns,
                        mapped_ns: me.mapped_ns,
                        finished_ns: $t,
                        inferences: me.req.inferences,
                        inference_latency_ns: me.inference_latency.clone(),
                        // Pure compute span per inference: sum over layers of
                        // the slowest segment (segments run in parallel).
                        compute_ns: {
                            let per_inf: f64 = me
                                .results
                                .iter()
                                .map(|layer| {
                                    layer.iter().map(|r| r.latency_ns).fold(0.0f64, f64::max)
                                })
                                .sum();
                            vec![per_inf; me.req.inferences as usize]
                        },
                        comm_ns: me.comm_ns.clone(),
                        segments: me.mapping.total_segments(),
                        breakdown: bd_final,
                    }
                };
                notify!(on_model_finished(&outcome));
                trace_hook!(tracer, |tr| {
                    tr.async_end(
                        crate::trace::TraceCategories::REQUEST,
                        crate::trace::PID_REQUEST,
                        outcome.tenant as u32,
                        "request",
                        outcome.id as u64,
                        $t,
                        vec![("state", "finished".into())],
                    );
                });
                if !sink.on_outcome(&outcome, $t) {
                    *stop_requested = true;
                }
                if *retain {
                    outcomes.push(outcome);
                } else {
                    // Constant-memory streaming: drop the finished state
                    // and recycle the slot.  An instance can only finish
                    // after every one of its weight/activation flows
                    // completed (each completion removes its flow_of
                    // entry), so no stale flow can be misattributed to
                    // the slot's next occupant.
                    debug_assert!(
                        flow_of.values().all(|v| v.0 != inst),
                        "retired instance {inst} still has in-flight flows"
                    );
                    instances[inst].retire();
                    free_slots.push(inst);
                }
                push_event(queue, seq, $t, Event::TryMap);
            }};
        }

        // ------------------------------------------------------ main loop
        loop {
            if *stop_requested {
                return Ok(RunStatus::Stopped);
            }
            let t_queue = queue.peek().map(|Reverse(e)| e.t).unwrap_or(TimeNs::MAX);
            // At most one upcoming arrival is materialized (inside the
            // source's peek buffer); the rest stay in the generator until
            // virtual time reaches them.
            let t_arrival = source.peek_arrival_ns().unwrap_or(TimeNs::MAX);
            let t_next = t_queue.min(t_arrival);
            if net.has_active() {
                // The network never advances past the epoch boundary:
                // completions after `until` belong to a later epoch.
                if let Some(c) = net.advance_until(t_next.min(until)) {
                    *now = (*now).max(c.time);
                    for (node, t, pj) in net.drain_energy_events() {
                        power.add_event(node, t, pj);
                        notify!(on_noc_energy(node, t, pj));
                    }
                    trace_hook!(tracer, |tr| {
                        use crate::trace::TraceCategories as TC;
                        if tr.enabled(TC::NOI) {
                            for ev in net.drain_link_trace() {
                                tr.span(
                                    TC::NOI,
                                    crate::trace::PID_NOI,
                                    ev.link as u32,
                                    format!("flow {}", ev.flow),
                                    ev.start_ns,
                                    ev.dur_ns,
                                    vec![("stall_ns", ev.stall_ns.into())],
                                );
                            }
                        }
                    });
                    let Some((inst, layer, inference)) = flow_of.remove(&c.id) else {
                        continue;
                    };
                    if instances[inst].finished {
                        continue;
                    }
                    if layer == WEIGHT_LAYER {
                        instances[inst].weight_flows -= 1;
                        if instances[inst].weight_flows == 0 {
                            trace_hook!(tracer, |tr| {
                                if let Some(bd) = instances[inst].bd.as_deref_mut() {
                                    bd.on_comm_done(WEIGHT_LAYER, 0, c.time);
                                }
                            });
                            instances[inst].layers[0].ready.push_back(0);
                            dispatch_ready!(inst, 0, c.time);
                        }
                    } else {
                        let left = instances[inst].inflows.get_mut(&(layer, inference)).unwrap();
                        *left -= 1;
                        if *left == 0 {
                            instances[inst].inflows.remove(&(layer, inference));
                            if let Some(t0) =
                                instances[inst].comm_start.remove(&(layer, inference))
                            {
                                let span = (c.time - t0) as f64;
                                if let Some(slot) =
                                    instances[inst].comm_ns.get_mut(inference as usize)
                                {
                                    *slot += span;
                                }
                            }
                            trace_hook!(tracer, |tr| {
                                if let Some(bd) = instances[inst].bd.as_deref_mut() {
                                    bd.on_comm_done(layer, inference, c.time);
                                }
                            });
                            instances[inst].layers[layer].ready.push_back(inference);
                            dispatch_ready!(inst, layer, c.time);
                        }
                    }
                    continue;
                }
            }
            if t_next > until {
                // Everything at or before the boundary is processed.  Only
                // in-flight network traffic (no queryable completion time)
                // can still be pending when `t_next` is `MAX`.
                return Ok(if t_next == TimeNs::MAX && !net.has_active() {
                    RunStatus::Idle
                } else {
                    RunStatus::Paused { next_event_ns: t_next }
                });
            }
            if t_next == TimeNs::MAX {
                // Queue empty, no arrivals left, network idle.
                return Ok(RunStatus::Idle);
            }
            *now = (*now).max(t_next);
            crate::util::logging::set_sim_now(*now);
            // The network flushes hop energy only on flow completions;
            // when a thermal consumer drains windows in-loop (DTM, or a
            // streaming sink feeding the Native/Auto stepper), book
            // whatever the engine has generated so far first — energy
            // landing behind a drain cursor folds into drained totals
            // without ever reaching the RC integration.
            if dtm_rt.is_some() || stepper.is_some() {
                for (node, t, pj) in net.drain_energy_events() {
                    power.add_event(node, t, pj);
                    notify!(on_noc_energy(node, t, pj));
                }
            }
            if let Some(d) = dtm_rt.as_mut() {
                let _prof_dtm = crate::prof::scope(crate::prof::Subsystem::Dtm);
                // Close elapsed control windows first so the operating
                // points the next events see reflect the window that
                // just ended.
                d.on_advance(*now, &mut *power, &mut *sink)?;
            }
            trace_hook!(tracer, |tr| {
                use crate::trace::TraceCategories as TC;
                if tr.gauge_due(*now) {
                    let busy = chiplets.iter().filter(|c| c.busy).count();
                    tr.counter(
                        TC::GAUGES,
                        crate::trace::PID_GAUGE,
                        "queue depth",
                        *now,
                        vec![("requests", arb.len() as f64)],
                    );
                    tr.counter(
                        TC::GAUGES,
                        crate::trace::PID_GAUGE,
                        "busy chiplets",
                        *now,
                        vec![("busy", busy as f64)],
                    );
                    if let Some(d) = dtm_rt.as_ref() {
                        tr.counter(
                            TC::GAUGES,
                            crate::trace::PID_GAUGE,
                            "thermal",
                            *now,
                            vec![
                                ("hottest_c", d.hottest_c()),
                                ("throttled_chiplets", d.throttled_chiplets() as f64),
                            ],
                        );
                    }
                }
                if let Some(d) = dtm_rt.as_ref() {
                    if tr.enabled(TC::DTM) {
                        let n = d.throttled_chiplets();
                        if tr.throttled_changed(n) {
                            tr.instant(
                                TC::DTM,
                                crate::trace::PID_GAUGE,
                                0,
                                "governor",
                                *now,
                                vec![
                                    ("throttled_chiplets", (n as u64).into()),
                                    ("max_dvfs_level", (d.max_dvfs_level() as u64).into()),
                                    ("hottest_c", d.hottest_c().into()),
                                ],
                            );
                        }
                    }
                }
            });
            let keep_going = sink.on_advance(
                *now,
                &mut PowerPort::new(&mut *power, stepper.as_mut(), &mut *thermal_err),
            );
            if let Some(e) = thermal_err.take() {
                return Err(e);
            }
            if !keep_going {
                return Ok(RunStatus::Stopped);
            }
            if self.params.max_sim_time_ns > 0 && *now > self.params.max_sim_time_ns {
                // The sim-time log prefix carries the exact truncation
                // point; the id-free message dedups across sweep repeats.
                crate::warn_once!(
                    "max_sim_time {} ns reached; truncating run",
                    self.params.max_sim_time_ns
                );
                return Ok(RunStatus::Stopped);
            }
            // Arrivals win ties with queue events, matching the old
            // pre-pushed ordering (arrivals held the smallest seqs).
            if t_arrival <= t_queue {
                let req = source.next_request().expect("peeked arrival");
                trace_hook!(tracer, |tr| {
                    use crate::trace::TraceCategories as TC;
                    if tr.enabled(TC::REQUEST) {
                        let tenant = req.tenant as u32;
                        tr.name_thread(
                            crate::trace::PID_REQUEST,
                            tenant,
                            &format!("tenant {}", req.tenant),
                        );
                        tr.async_begin(
                            TC::REQUEST,
                            crate::trace::PID_REQUEST,
                            tenant,
                            "request",
                            req.id as u64,
                            req.arrival_ns,
                            vec![("kind", req.kind.name().into())],
                        );
                    }
                });
                crate::prof::count(crate::prof::Counter::Events, 1);
                arb.push(req);
                try_map_models!(t_next);
                continue;
            }
            let Some(Reverse(entry)) = queue.pop() else {
                return Ok(RunStatus::Idle);
            };
            crate::prof::count(crate::prof::Counter::Events, 1);
            match entry.ev {
                Event::TryMap => {
                    try_map_models!(entry.t);
                }
                Event::ComputeDone { inst, layer, seg, inference } => {
                    let cid = instances[inst].mapping.layers[layer][seg].chiplet;
                    chiplets[cid].busy = false;
                    start_chiplet_if_idle!(cid, entry.t);
                    if instances[inst].finished {
                        // Aborted-by-fault: the segment's chiplet is freed
                        // above; everything else about the instance is
                        // already torn down.
                        continue;
                    }
                    let nsegs = instances[inst].mapping.layers[layer].len();
                    let done = {
                        let lr = &mut instances[inst].layers[layer];
                        let cnt = lr.segs_done.entry(inference).or_insert(0);
                        *cnt += 1;
                        *cnt == nsegs
                    };
                    if !done {
                        continue;
                    }
                    // Whole layer finished this inference.
                    {
                        let lr = &mut instances[inst].layers[layer];
                        lr.segs_done.remove(&inference);
                        lr.completed += 1;
                        lr.done_ns.insert(inference, entry.t);
                    }
                    let nlayers = instances[inst].layers.len();
                    let n_inf = instances[inst].req.inferences;
                    // Free a downstream credit for the upstream stage.
                    if self.params.pipelined && layer > 0 {
                        dispatch_ready!(inst, layer - 1, entry.t);
                    }
                    // Pipelined: layer 0 chains itself to the next inference.
                    if self.params.pipelined && layer == 0 && inference + 1 < n_inf {
                        instances[inst].layers[0].ready.push_back(inference + 1);
                        dispatch_ready!(inst, 0, entry.t);
                    }
                    if layer + 1 < nlayers {
                        emit_layer_flows!(inst, layer, inference, entry.t);
                    } else {
                        // Inference complete.
                        let start = *instances[inst]
                            .inference_start
                            .get(&inference)
                            .unwrap_or(&instances[inst].mapped_ns);
                        instances[inst].inference_latency.push(entry.t - start);
                        if !self.params.pipelined && inference + 1 < n_inf {
                            instances[inst].layers[0].ready.push_back(inference + 1);
                            dispatch_ready!(inst, 0, entry.t);
                        }
                        if instances[inst].inference_latency.len() == n_inf as usize {
                            finish_instance!(inst, entry.t);
                        }
                    }
                }
                Event::Fault(i) => {
                    let t = entry.t;
                    let tog = fault.as_ref().expect("fault event without runtime").toggles[i];
                    // Resolve the toggle to the directed links it governs
                    // (a link fault takes both directions of the physical
                    // channel with it; a router fault severs every link
                    // touching the node).
                    let mut links_touched: Vec<usize> = Vec::new();
                    match (tog.kind, tog.target) {
                        (FaultKind::Link, FaultTarget::NodePair(a, b)) => {
                            for (l, link) in self.topo.links.iter().enumerate() {
                                if (link.src == a && link.dst == b)
                                    || (link.src == b && link.dst == a)
                                {
                                    links_touched.push(l);
                                }
                            }
                        }
                        (FaultKind::Link, FaultTarget::Index(l)) => {
                            links_touched.push(l);
                            let (a, b) = (self.topo.links[l].src, self.topo.links[l].dst);
                            for (r, link) in self.topo.links.iter().enumerate() {
                                if link.src == b && link.dst == a {
                                    links_touched.push(r);
                                }
                            }
                        }
                        (FaultKind::Router, FaultTarget::Index(n)) => {
                            links_touched.extend(self.topo.out_links[n].iter().copied());
                            links_touched.extend(self.topo.in_links[n].iter().copied());
                        }
                        _ => {}
                    }
                    links_touched.sort_unstable();
                    links_touched.dedup();
                    if tog.kind == FaultKind::Link && links_touched.is_empty() {
                        crate::warn_once!(
                            "fault plan targets link {:?} but no such link exists; ignoring",
                            tog.target
                        );
                        continue;
                    }
                    // Canonical resource id for the downtime ledger and
                    // timeline: smallest directed link index for link
                    // faults, the node/chiplet index otherwise.
                    let canonical = match (tog.kind, tog.target) {
                        (FaultKind::Link, _) => links_touched.first().copied().unwrap_or(0),
                        (_, FaultTarget::Index(x)) => x,
                        _ => 0,
                    };
                    {
                        let f = fault.as_mut().expect("fault event without runtime");
                        if tog.up {
                            f.report.repairs += 1;
                            f.downtime.up(tog.kind, canonical, t);
                        } else {
                            f.report.injected += 1;
                            f.downtime.down(tog.kind, canonical, t);
                            if tog.kind == FaultKind::Sensor {
                                f.report.sensor_faults += 1;
                            }
                        }
                        f.report.timeline.push(FaultTimelineEntry {
                            at_ns: t,
                            kind: tog.kind.name(),
                            target: canonical,
                            up: tog.up,
                        });
                    }
                    trace_hook!(tracer, |tr| {
                        use crate::trace::TraceCategories as TC;
                        if tr.enabled(TC::FAULT) {
                            tr.instant(
                                TC::FAULT,
                                crate::trace::PID_FAULT,
                                0,
                                if tog.up { "repair" } else { "fail" },
                                t,
                                vec![
                                    ("kind", tog.kind.name().into()),
                                    ("target", (canonical as u64).into()),
                                ],
                            );
                        }
                    });
                    match tog.kind {
                        FaultKind::Link | FaultKind::Router => {
                            // Ref-count the directed links (link + router
                            // faults on the same channel stack); reroute
                            // and let the engine adopt the new tables only
                            // when the derived mask actually changed.
                            let mut to_abort: Vec<usize> = Vec::new();
                            {
                                let f = fault.as_mut().expect("fault runtime");
                                let mut changed = false;
                                for &l in &links_touched {
                                    let c = &mut f.link_down_cnt[l];
                                    if tog.up {
                                        let was = *c;
                                        *c = c.saturating_sub(1);
                                        changed |= was == 1;
                                    } else {
                                        *c += 1;
                                        changed |= *c == 1;
                                    }
                                }
                                if changed {
                                    let mask: Vec<bool> =
                                        f.link_down_cnt.iter().map(|&c| c > 0).collect();
                                    // Rebuild from the pristine topology:
                                    // an all-up mask restores the original
                                    // routing exactly (mesh X-Y included).
                                    f.topo = self.topo.clone();
                                    if mask.iter().any(|&d| d) {
                                        f.topo.apply_link_mask(&mask);
                                    }
                                    for (id, spec) in net.apply_fault(&f.topo, &mask) {
                                        let Some(owner) = flow_of.remove(&id) else {
                                            continue;
                                        };
                                        if instances[owner.0].finished {
                                            continue;
                                        }
                                        if f.topo.reachable(spec.src, spec.dst) {
                                            // Restart the transfer over
                                            // the rerouted path.
                                            f.report.reroutes += 1;
                                            let nid = net.inject(spec, t);
                                            flow_of.insert(nid, owner);
                                        } else {
                                            f.report.flow_fails += 1;
                                            to_abort.push(owner.0);
                                        }
                                    }
                                }
                            }
                            to_abort.sort_unstable();
                            to_abort.dedup();
                            for v in to_abort {
                                abort_instance!(v, t);
                            }
                        }
                        FaultKind::Chiplet => {
                            if let FaultTarget::Index(c) = tog.target {
                                let mut victims: Vec<usize> = Vec::new();
                                {
                                    let f = fault.as_mut().expect("fault runtime");
                                    if tog.up {
                                        f.chiplet_dead_cnt[c] =
                                            f.chiplet_dead_cnt[c].saturating_sub(1);
                                        if f.chiplet_dead_cnt[c] == 0 {
                                            // Capacity came back: remap.
                                            push_event(queue, seq, t, Event::TryMap);
                                        }
                                    } else {
                                        f.chiplet_dead_cnt[c] += 1;
                                        if f.chiplet_dead_cnt[c] == 1 {
                                            // Every request with state on
                                            // the chiplet dies with it
                                            // (deterministic order:
                                            // instance index).
                                            victims = instances
                                                .iter()
                                                .enumerate()
                                                .filter(|(_, inst)| {
                                                    !inst.finished
                                                        && inst.mapping.layers.iter().any(
                                                            |layer| {
                                                                layer
                                                                    .iter()
                                                                    .any(|s| s.chiplet == c)
                                                            },
                                                        )
                                                })
                                                .map(|(i, _)| i)
                                                .collect();
                                        }
                                    }
                                }
                                for v in victims {
                                    abort_instance!(v, t);
                                }
                            }
                        }
                        FaultKind::Sensor => {
                            if let (FaultTarget::Index(c), Some(d)) =
                                (tog.target, dtm_rt.as_mut())
                            {
                                // The governor acts on the lie from the
                                // next control window on; repair restores
                                // the honest reading.
                                d.set_sensor_fault(
                                    c,
                                    if tog.up { None } else { tog.sensor.map(|m| (m, t)) },
                                );
                            }
                        }
                        // Board crashes are fleet-level; the dispatcher
                        // executes them (a single board has no "outside"
                        // to fail from).
                        FaultKind::Board => {}
                    }
                }
            }
        }

    }

    /// Consume the session into the final [`SimReport`]: book the
    /// network's residual energy, fold the live power tail into the
    /// thermal/DTM state, and notify observers of completion.
    pub fn finish_run(
        &mut self,
        s: RunSession,
        sink: &mut dyn StreamSink,
    ) -> anyhow::Result<SimReport> {
        let RunSession {
            wall_start,
            mut net,
            mut power,
            stepper,
            dtm_rt,
            fault,
            chiplets,
            tenant_traffic,
            outcomes,
            dropped,
            now,
            compute_energy,
            instances,
            mut arb,
            ..
        } = s;
        crate::util::logging::clear_sim_now();
        for (node, t, pj) in net.drain_energy_events() {
            power.add_event(node, t, pj);
            for ob in &self.observers {
                ob.lock().expect("observer lock").on_noc_energy(node, t, pj);
            }
        }
        // Flush the recorder: residual link spans plus a terminal event
        // for everything still queued or in flight, so every request
        // track reaches a terminal state even on truncated runs.
        #[cfg(feature = "trace")]
        if let Some(h) = &self.tracer {
            let mut tr = h.lock().expect("trace recorder lock");
            use crate::trace::{TraceCategories as TC, PID_NOI, PID_REQUEST};
            if tr.enabled(TC::NOI) {
                for ev in net.drain_link_trace() {
                    tr.span(
                        TC::NOI,
                        PID_NOI,
                        ev.link as u32,
                        format!("flow {}", ev.flow),
                        ev.start_ns,
                        ev.dur_ns,
                        vec![("stall_ns", ev.stall_ns.into())],
                    );
                }
            }
            for i in instances.iter().filter(|i| !i.finished) {
                tr.async_end(
                    TC::REQUEST,
                    PID_REQUEST,
                    i.req.tenant as u32,
                    "request",
                    i.req.id as u64,
                    now,
                    vec![("state", "truncated".into())],
                );
            }
            for req in arb.drain_pending() {
                tr.async_end(
                    TC::REQUEST,
                    PID_REQUEST,
                    req.tenant as u32,
                    "request",
                    req.id as u64,
                    now,
                    vec![("state", "truncated".into())],
                );
            }
        }
        #[cfg(not(feature = "trace"))]
        let _ = (&instances, &mut arb);
        let span_ns = now;
        // Close the fault report: availability folds open outages to the
        // end of the run.  `None` (plan absent or armed empty) keeps the
        // report — and the fingerprint — identical to a faultless run.
        let fault = fault.map(|mut f| {
            f.report.finish(&f.downtime, span_ns);
            f.report
        });
        let link_util =
            crate::noc::LinkUtilization::from_busy(&net.link_busy_ns(), span_ns);
        let hi = span_ns.saturating_sub(self.params.cooldown_ns).max(self.params.warmup_ns);
        // Fold the still-live power tail into the thermal state and roll
        // the summary up.  Whatever drained mid-run already went through
        // the stepper (PowerPort) or the DTM controller, so the summary
        // covers the whole horizon even for streaming runs.
        let (thermal, dtm) = match (dtm_rt, stepper) {
            (Some(d), _) => {
                let rep = d.finish(&power, &mut *sink)?;
                let thermal = summarize_thermal(rep.solver, rep.steps, &rep.final_temps_c);
                (thermal, Some(rep))
            }
            (None, Some(mut st)) => {
                st.ingest_live(&power)?;
                st.flush()?;
                (summarize_thermal(st.solver(), st.steps(), &st.chiplet_temps_c()), None)
            }
            (None, None) => (None, None),
        };
        crate::prof::count(crate::prof::Counter::SimsCompleted, 1);
        let wall_ns = wall_start.elapsed().as_nanos();
        let report = SimReport {
            outcomes,
            dropped,
            span_ns,
            power,
            chiplet_busy_ns: chiplets.iter().map(|c| c.busy_ns).collect(),
            comm_energy_pj: net.comm_energy_pj(),
            compute_energy_pj: compute_energy,
            noc_work: net.work_done(),
            link_util,
            tenant_comm: tenant_traffic.into_vec(),
            wall_ns,
            stats_window: (self.params.warmup_ns, hi),
            thermal,
            dtm,
            fault,
            // Host-timing data only; never part of the fingerprint.
            profile: crate::prof::snapshot(wall_ns as u64),
        };
        for ob in &self.observers {
            ob.lock().expect("observer lock").on_run_complete(&report);
        }
        Ok(report)
    }
}

/// Placement mask of `tenant` (`None` = unrestricted placement — the
/// single-tenant default, and the fallback for tenants beyond the table).
fn mask_of(masks: &Option<Vec<Vec<bool>>>, tenant: usize) -> Option<&[bool]> {
    masks.as_ref().and_then(|m| m.get(tenant)).map(|v| v.as_slice())
}

/// AND a tenant placement mask with the fault-time alive mask.  With at
/// most one side present that side is returned as-is (no allocation);
/// with both, the conjunction lands in `buf`.
fn combine_allowed<'a>(
    tenant: Option<&'a [bool]>,
    alive: Option<&'a [bool]>,
    buf: &'a mut Vec<bool>,
) -> Option<&'a [bool]> {
    match (tenant, alive) {
        (None, None) => None,
        (Some(m), None) => Some(m),
        (None, Some(a)) => Some(a),
        (Some(m), Some(a)) => {
            buf.clear();
            buf.extend(m.iter().zip(a).map(|(&x, &y)| x && y));
            Some(buf.as_slice())
        }
    }
}

/// Zero-contention latency estimate of one flow, feeding the breakdown's
/// NoI-serialization floor: the head packet pipelines through the route
/// (hop latency + one packet serialization per hop) and the remaining
/// payload streams behind it at link rate.  Matches the packet engine's
/// uncontended multi-packet latency exactly; for the flit engine it is
/// the same quantity up to the router-pipeline approximation.
#[cfg(feature = "trace")]
fn ideal_flow_ns(topo: &Topology, src: usize, dst: usize, bytes: u64) -> u64 {
    let Some(path) = topo.path(src, dst) else {
        return 0; // unreachable: no serialization floor to report
    };
    if path.is_empty() {
        return 0;
    }
    let hop = topo.hop_ns().round() as u64;
    let link0 = path[0];
    let pkt_bytes = crate::noc::engine::PACKET_FLITS * topo.links[link0].width_bytes;
    let bytes = bytes.max(1);
    let pkt_ser = (topo.ser_ns(link0, bytes.min(pkt_bytes)).round() as u64).max(1);
    let full_ser = (topo.ser_ns(link0, bytes).round() as u64).max(1);
    path.len() as u64 * (hop + pkt_ser) + full_ser.saturating_sub(pkt_ser)
}

/// Roll the stepper's final state up into the report's summary (`None`
/// when no power was ever integrated, matching the pre-stepper
/// behaviour on empty runs).
fn summarize_thermal(
    solver: &'static str,
    steps: usize,
    temps_c: &[f64],
) -> Option<ThermalSummary> {
    if steps == 0 {
        return None;
    }
    let hottest = temps_c.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let coolest = temps_c.iter().cloned().fold(f64::INFINITY, f64::min);
    Some(ThermalSummary {
        solver,
        steps,
        hottest_c: hottest,
        coolest_c: coolest,
        spread_k: hottest - coolest,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::ModelKind;

    fn small_params() -> SimParams {
        SimParams {
            inferences_per_model: 2,
            warmup_ns: 0,
            cooldown_ns: 0,
            ..SimParams::default()
        }
    }

    fn sim(hw: HardwareConfig, params: SimParams) -> Simulation {
        Simulation::builder().hardware(hw).params(params).build().expect("valid config")
    }

    #[test]
    fn single_model_completes() {
        let hw = HardwareConfig::homogeneous_mesh(4, 4);
        let report = sim(hw, small_params())
            .run(WorkloadConfig::single(ModelKind::ResNet18))
            .unwrap();
        assert_eq!(report.outcomes.len(), 1);
        assert_eq!(report.outcomes[0].inference_latency_ns.len(), 2);
        assert!(report.outcomes[0].mean_latency_ns() > 0.0);
        assert!(report.dropped.is_empty());
    }

    #[test]
    fn pipelined_is_not_slower_in_throughput() {
        let hw = HardwareConfig::homogeneous_mesh(4, 4);
        let mut p1 = small_params();
        p1.inferences_per_model = 8;
        let mut p2 = p1.clone();
        p2.pipelined = true;
        let r_seq = sim(hw.clone(), p1)
            .run(WorkloadConfig::single(ModelKind::ResNet18))
            .unwrap();
        let r_pipe = sim(hw, p2)
            .run(WorkloadConfig::single(ModelKind::ResNet18))
            .unwrap();
        // Pipelining overlaps layers: total completion time must shrink.
        assert!(
            r_pipe.outcomes[0].finished_ns < r_seq.outcomes[0].finished_ns,
            "pipe {} !< seq {}",
            r_pipe.outcomes[0].finished_ns,
            r_seq.outcomes[0].finished_ns
        );
    }

    #[test]
    fn oversized_model_is_dropped_not_deadlocked() {
        let hw = HardwareConfig::homogeneous_mesh(2, 2); // 8 MiB total
        let report = sim(hw, small_params())
            .run(WorkloadConfig::single(ModelKind::AlexNet))
            .unwrap();
        assert_eq!(report.outcomes.len(), 0);
        assert_eq!(report.dropped.len(), 1);
    }

    #[test]
    fn stream_of_models_all_finish() {
        let hw = HardwareConfig::homogeneous_mesh(8, 8);
        let mut params = small_params();
        params.pipelined = true;
        let wl = WorkloadConfig::from_kinds(&[
            ModelKind::ResNet18,
            ModelKind::AlexNet,
            ModelKind::ResNet34,
            ModelKind::ResNet18,
        ]);
        let report = sim(hw, params).run(wl).unwrap();
        assert_eq!(report.outcomes.len() + report.dropped.len(), 4);
        assert!(report.outcomes.len() >= 3);
        // Power was tracked.
        assert!(report.power.num_bins() > 0);
        assert!(report.comm_energy_pj > 0.0);
        assert!(report.compute_energy_pj > 0.0);
    }

    #[test]
    fn contention_from_parallel_models_inflates_latency() {
        // One ResNet18 alone vs four running concurrently on the same mesh.
        let hw = HardwareConfig::homogeneous_mesh(10, 10);
        let mut params = small_params();
        params.pipelined = true;
        params.inferences_per_model = 4;
        let solo = sim(hw.clone(), params.clone())
            .run(WorkloadConfig::single(ModelKind::ResNet18))
            .unwrap();
        let busy = sim(hw, params)
            .run(WorkloadConfig::from_kinds(&[ModelKind::ResNet18; 4]))
            .unwrap();
        let lat_solo = solo.mean_latency_of(ModelKind::ResNet18).unwrap();
        let lat_busy = busy.mean_latency_of(ModelKind::ResNet18).unwrap();
        assert!(
            lat_busy > lat_solo,
            "contention must inflate latency: busy {lat_busy} !> solo {lat_solo}"
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let hw = HardwareConfig::homogeneous_mesh(6, 6);
        let run = || {
            sim(hw.clone(), small_params())
                .run(WorkloadConfig::from_kinds(&[ModelKind::ResNet18, ModelKind::AlexNet]))
                .unwrap()
        };
        let a = run();
        let b = run();
        assert_eq!(a.span_ns, b.span_ns);
        assert_eq!(a.fingerprint(), b.fingerprint());
    }

    #[test]
    fn same_simulation_is_reusable() {
        // Two run() calls on one Simulation are independent and identical.
        let hw = HardwareConfig::homogeneous_mesh(4, 4);
        let mut s = sim(hw, small_params());
        let a = s.run(WorkloadConfig::single(ModelKind::ResNet18)).unwrap();
        let b = s.run(WorkloadConfig::single(ModelKind::ResNet18)).unwrap();
        assert_eq!(a.fingerprint(), b.fingerprint());
    }

    #[test]
    fn power_observer_matches_builtin_tracker() {
        let hw = HardwareConfig::homogeneous_mesh(4, 4);
        let probe = Arc::new(Mutex::new(PowerTracker::new(
            hw.num_chiplets(),
            crate::POWER_BIN_NS,
        )));
        let report = Simulation::builder()
            .hardware(hw.clone())
            .params(small_params())
            .observer(probe.clone())
            .build()
            .unwrap()
            .run(WorkloadConfig::single(ModelKind::ResNet18))
            .unwrap();
        // The attached probe saw every energy booking the built-in
        // tracker did (baselines differ: the probe has none set).
        let p = probe.lock().unwrap();
        for c in 0..hw.num_chiplets() {
            let a = report.power.dynamic_energy_pj(c);
            let b = p.dynamic_energy_pj(c);
            assert!((a - b).abs() <= 1e-9 * a.abs().max(1.0), "chiplet {c}: {a} != {b}");
        }
    }

    #[test]
    fn event_counter_observer_sees_lifecycle() {
        let hw = HardwareConfig::homogeneous_mesh(6, 6);
        let counter = Arc::new(Mutex::new(EventCounter::default()));
        let report = Simulation::builder()
            .hardware(hw)
            .params(small_params())
            .observer(counter.clone())
            .build()
            .unwrap()
            .run(WorkloadConfig::from_kinds(&[ModelKind::ResNet18, ModelKind::AlexNet]))
            .unwrap();
        let c = counter.lock().unwrap();
        assert_eq!(c.mapped, report.outcomes.len());
        assert_eq!(c.finished, report.outcomes.len());
        assert_eq!(c.dropped, report.dropped.len());
        assert!(c.compute_events > 0);
        assert!((c.compute_energy_pj - report.compute_energy_pj).abs() < 1e-6);
    }

    #[test]
    fn epoch_bounded_session_matches_monolithic() {
        // Chopping a run into bounded virtual-time epochs must not change
        // a single byte of the result — the fleet layer depends on this.
        let hw = HardwareConfig::homogeneous_mesh(6, 6);
        let kinds = [ModelKind::ResNet18, ModelKind::AlexNet, ModelKind::ResNet34];
        let mono = sim(hw.clone(), small_params())
            .run(WorkloadConfig::from_kinds(&kinds))
            .unwrap();
        let mut s = sim(hw, small_params());
        let seed = s.params().seed;
        let stream = WorkloadStream::from_kinds(
            &kinds,
            s.params().inferences_per_model,
            WorkloadConfig::from_kinds(&kinds).injection_interval_ns,
        );
        let mut source = BatchSource::new(stream.requests);
        let mut sink = NullSink;
        let mut session = s.begin_run(seed, sink.retain_state()).unwrap();
        let epoch_ns: TimeNs = 20_000; // far smaller than the run span
        let mut until = epoch_ns;
        let mut epochs = 0usize;
        loop {
            match s.advance_run(&mut session, &mut source, &mut sink, until).unwrap() {
                RunStatus::Idle | RunStatus::Stopped => break,
                RunStatus::Paused { .. } => {
                    until += epoch_ns;
                    epochs += 1;
                }
            }
        }
        assert!(epochs > 2, "epoch size too coarse to exercise pausing: {epochs}");
        let chopped = s.finish_run(session, &mut sink).unwrap();
        assert_eq!(mono.fingerprint(), chopped.fingerprint());
        assert_eq!(mono.span_ns, chopped.span_ns);
        assert_eq!(mono.outcomes.len(), chopped.outcomes.len());
    }

    #[test]
    fn custom_network_factory_is_used() {
        // Injecting the flit engine explicitly must match selecting it
        // via params.noc_fidelity.
        let hw = HardwareConfig::homogeneous_mesh(4, 4);
        let mut p = small_params();
        p.noc_fidelity = NocFidelity::Flit;
        let via_params = sim(hw.clone(), p)
            .run(WorkloadConfig::single(ModelKind::ResNet18))
            .unwrap();
        let via_factory = Simulation::builder()
            .hardware(hw)
            .params(small_params())
            .network(|topo| Box::new(FlitEngine::new(topo.clone())))
            .build()
            .unwrap()
            .run(WorkloadConfig::single(ModelKind::ResNet18))
            .unwrap();
        assert_eq!(via_params.fingerprint(), via_factory.fingerprint());
    }

    #[test]
    fn network_fidelity_survives_a_later_params_call() {
        let hw = HardwareConfig::homogeneous_mesh(4, 4);
        // .params() after .network_fidelity() must not revert the choice.
        let a = Simulation::builder()
            .network_fidelity(NocFidelity::Flit)
            .hardware(hw.clone())
            .params(small_params()) // carries the Packet default
            .build()
            .unwrap()
            .run(WorkloadConfig::single(ModelKind::ResNet18))
            .unwrap();
        let mut p = small_params();
        p.noc_fidelity = NocFidelity::Flit;
        let b = sim(hw, p).run(WorkloadConfig::single(ModelKind::ResNet18)).unwrap();
        assert_eq!(a.fingerprint(), b.fingerprint());
    }

    #[test]
    fn build_rejects_zero_chiplet_grid() {
        let hw = HardwareConfig::homogeneous_mesh(0, 4);
        let err = Simulation::builder().hardware(hw).build().err().expect("must fail");
        assert!(err.to_string().contains("zero chiplets"), "{err}");
    }

    #[test]
    fn build_rejects_io_only_hardware() {
        let mut hw = HardwareConfig::homogeneous_mesh(2, 2);
        hw.chiplet_types = vec![crate::config::ChipletTypeParams::io_die()];
        hw.type_of = vec![0; 4];
        let err = Simulation::builder().hardware(hw).build().err().expect("must fail");
        assert!(err.to_string().contains("no compute chiplets"), "{err}");
    }

    #[test]
    fn native_thermal_summary_is_populated() {
        let hw = HardwareConfig::homogeneous_mesh(4, 4);
        let report = Simulation::builder()
            .hardware(hw)
            .params(small_params())
            .thermal(ThermalSpec::Native { stride_bins: 10 })
            .build()
            .unwrap()
            .run(WorkloadConfig::single(ModelKind::ResNet18))
            .unwrap();
        let th = report.thermal.expect("thermal summary");
        assert_eq!(th.solver, "native");
        assert!(th.steps > 0);
        assert!(th.hottest_c >= th.coolest_c);
        assert!(th.spread_k >= 0.0);
        assert!(report.dtm.is_none());
    }

    #[test]
    fn in_loop_dtm_attaches_report_and_thermal_summary() {
        use crate::dtm::GovernorSpec;
        let hw = HardwareConfig::homogeneous_mesh(4, 4);
        let report = Simulation::builder()
            .hardware(hw)
            .params(small_params())
            .thermal(ThermalSpec::InLoop {
                window_ns: 10_000,
                governor: GovernorSpec::noop(200.0),
            })
            .build()
            .unwrap()
            .run(WorkloadConfig::single(ModelKind::ResNet18))
            .unwrap();
        let dtm = report.dtm.as_ref().expect("dtm report");
        assert_eq!(dtm.governor, "noop");
        assert!(dtm.windows > 0, "run spans several control windows");
        assert!(dtm.steps > 0);
        assert_eq!(dtm.ceiling_violations, 0, "a 200 °C ceiling cannot be hit");
        assert_eq!(dtm.throttle_residency, 0.0);
        assert!(!dtm.timeline.is_empty());
        let th = report.thermal.expect("in-loop runs still attach a summary");
        assert_eq!(th.solver, "native");
        assert!(th.hottest_c >= th.coolest_c);
    }

    #[test]
    fn noop_dtm_does_not_perturb_execution() {
        use crate::dtm::GovernorSpec;
        let hw = HardwareConfig::homogeneous_mesh(4, 4);
        let plain = sim(hw.clone(), small_params())
            .run(WorkloadConfig::single(ModelKind::ResNet18))
            .unwrap();
        let dtm = Simulation::builder()
            .hardware(hw)
            .params(small_params())
            .thermal(ThermalSpec::InLoop {
                window_ns: 5_000,
                governor: GovernorSpec::noop(200.0),
            })
            .build()
            .unwrap()
            .run(WorkloadConfig::single(ModelKind::ResNet18))
            .unwrap();
        assert_eq!(plain.span_ns, dtm.span_ns);
        assert_eq!(
            plain.compute_energy_pj.to_bits(),
            dtm.compute_energy_pj.to_bits(),
            "a 1.0x operating point must not change booked energy"
        );
        assert_eq!(
            plain.outcomes[0].inference_latency_ns,
            dtm.outcomes[0].inference_latency_ns
        );
    }

    #[test]
    fn aggressive_throttle_slows_execution_and_reports_residency() {
        use crate::dtm::GovernorSpec;
        let hw = HardwareConfig::homogeneous_mesh(4, 4);
        let plain = sim(hw.clone(), small_params())
            .run(WorkloadConfig::single(ModelKind::ResNet18))
            .unwrap();
        // A hot threshold below ambient throttles every window: the
        // feedback must visibly stretch execution and book less energy.
        let throttled = Simulation::builder()
            .hardware(hw)
            .params(small_params())
            .thermal(ThermalSpec::InLoop {
                window_ns: 5_000,
                governor: GovernorSpec::threshold_band(1.0, 0.0, 300.0),
            })
            .build()
            .unwrap()
            .run(WorkloadConfig::single(ModelKind::ResNet18))
            .unwrap();
        let dtm = throttled.dtm.as_ref().expect("dtm report");
        assert!(dtm.throttle_residency > 0.0, "always-hot threshold must throttle");
        assert!(dtm.transitions > 0);
        assert!(
            throttled.span_ns > plain.span_ns,
            "throttled compute must stretch the run: {} !> {}",
            throttled.span_ns,
            plain.span_ns
        );
        assert!(
            throttled.compute_energy_pj < plain.compute_energy_pj,
            "lower voltage must book less dynamic energy"
        );
    }
}
