//! Deprecated `GlobalManager` shim over the [`Simulation`] builder API.
//!
//! The co-simulation event loop (paper §III) lives in
//! [`crate::sim::simulation`]; this wrapper preserves the pre-builder
//! entry point for one release so downstream drivers migrate at their
//! own pace:
//!
//! ```text
//! GlobalManager::new(hw, params).run(wl)          // old
//! Simulation::builder().hardware(hw).params(params)
//!     .build()?.run(wl)                            // new
//! ```
//!
//! Unlike the pre-builder constructor, this shim never panics on backend
//! construction: if the configured backend cannot be opened (e.g. PJRT
//! without `make artifacts`), it logs the builder's error and falls back
//! to the analytical backend.

use crate::compute::ComputeBackend;
use crate::config::{ComputeBackendKind, HardwareConfig, SimParams, WorkloadConfig};
use crate::noc::topology::Topology;
use crate::sim::report::SimReport;
use crate::sim::simulation::Simulation;

/// The pre-builder co-simulation coordinator.
#[deprecated(
    note = "use chipsim::sim::Simulation::builder() — GlobalManager will be removed in the next release"
)]
pub struct GlobalManager {
    inner: Simulation,
}

#[allow(deprecated)]
impl GlobalManager {
    pub fn new(hw: HardwareConfig, params: SimParams) -> Self {
        let inner = match Simulation::builder()
            .hardware(hw.clone())
            .params(params.clone())
            .build()
        {
            Ok(sim) => sim,
            // Backend construction is the only fallible step beyond
            // validation; retry it analytically.  Validation errors
            // (impossible hardware/params) re-fail in the retry and
            // surface as a panic carrying the builder's message — the
            // pre-builder constructor also panicked on such configs.
            Err(e) if params.compute_backend != ComputeBackendKind::Analytical => {
                // Loud on stderr as well: library consumers without a
                // logger installed must still see that the numbers come
                // from a different backend than requested.
                eprintln!(
                    "warning: GlobalManager::new: {e:#}; falling back to the analytical \
                     compute backend"
                );
                log::warn!(
                    "GlobalManager::new: {e:#}; falling back to the analytical compute backend"
                );
                Simulation::builder()
                    .hardware(hw)
                    .params(SimParams {
                        compute_backend: ComputeBackendKind::Analytical,
                        ..params
                    })
                    .build()
                    .unwrap_or_else(|e| {
                        panic!("GlobalManager::new: invalid configuration: {e:#}")
                    })
            }
            Err(e) => panic!("GlobalManager::new: invalid configuration: {e:#}"),
        };
        GlobalManager { inner }
    }

    /// Override the compute backend (dependency injection for tests).
    pub fn with_backend(mut self, backend: Box<dyn ComputeBackend>) -> Self {
        self.inner.set_backend(backend);
        self
    }

    pub fn topology(&self) -> &Topology {
        self.inner.topology()
    }

    /// Run the co-simulation to completion.
    pub fn run(&mut self, workload: WorkloadConfig) -> anyhow::Result<SimReport> {
        self.inner.run(workload)
    }
}

#[cfg(test)]
mod tests {
    #![allow(deprecated)]
    use super::*;
    use crate::workload::ModelKind;

    #[test]
    fn shim_matches_builder_result() {
        let hw = HardwareConfig::homogeneous_mesh(4, 4);
        let params = SimParams {
            inferences_per_model: 2,
            warmup_ns: 0,
            cooldown_ns: 0,
            ..SimParams::default()
        };
        let old = GlobalManager::new(hw.clone(), params.clone())
            .run(WorkloadConfig::single(ModelKind::ResNet18))
            .unwrap();
        let new = Simulation::builder()
            .hardware(hw)
            .params(params)
            .build()
            .unwrap()
            .run(WorkloadConfig::single(ModelKind::ResNet18))
            .unwrap();
        assert_eq!(old.fingerprint(), new.fingerprint());
    }

    #[test]
    fn shim_does_not_panic_on_missing_pjrt_artifacts() {
        // Even if the PJRT artifacts are absent, construction must fall
        // back to the analytical backend instead of panicking.
        let hw = HardwareConfig::homogeneous_mesh(4, 4);
        let params = SimParams {
            compute_backend: ComputeBackendKind::Pjrt,
            inferences_per_model: 1,
            warmup_ns: 0,
            cooldown_ns: 0,
            ..SimParams::default()
        };
        let report = GlobalManager::new(hw, params)
            .run(WorkloadConfig::single(ModelKind::ResNet18))
            .unwrap();
        assert_eq!(report.outcomes.len(), 1);
    }
}
