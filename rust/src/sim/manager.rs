//! The Global Manager: CHIPSIM's co-simulation event loop (paper §III).

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, VecDeque};
use std::time::Instant;

use crate::compute::{ClassDispatchBackend, ComputeBackend, ComputeResult};
use crate::config::{ComputeBackendKind, HardwareConfig, NocFidelity, SimParams, WorkloadConfig};
use crate::mapping::{MemoryLedger, ModelMapping, NearestNeighborMapper};
use crate::noc::{engine::PacketEngine, flit::FlitEngine, topology::Topology};
use crate::noc::{FlowId, FlowSpec, NetworkSim};
use crate::power::PowerTracker;
use crate::sim::report::{ModelOutcome, SimReport};
use crate::workload::{ArbitrationQueue, ModelRequest, NeuralModel, WorkloadStream};
use crate::TimeNs;

/// Pipeline double-buffering depth: a stage may run at most this many
/// inferences ahead of its downstream consumer.
const PIPELINE_CREDITS: u32 = 2;

/// Sentinel "layer" index for ViT weight-load flows.
const WEIGHT_LAYER: usize = usize::MAX;

// ----------------------------------------------------------------- events

#[derive(Debug, Clone, PartialEq, Eq)]
enum Event {
    /// A model request enters the arbitration queue.
    Arrive(usize),
    /// Re-run arbitration (after an unmap or arrival).
    TryMap,
    /// A segment's compute finished on its chiplet.
    ComputeDone { inst: usize, layer: usize, seg: usize, inference: u32 },
}

#[derive(Debug, Clone, PartialEq, Eq)]
struct QEntry {
    t: TimeNs,
    seq: u64,
    ev: Event,
}

impl Ord for QEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.t, self.seq).cmp(&(other.t, other.seq))
    }
}
impl PartialOrd for QEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

// ------------------------------------------------------------- run state

#[derive(Debug, Default, Clone)]
struct LayerRuntime {
    /// Inferences with inputs ready, awaiting dispatch (credit/queue).
    ready: VecDeque<u32>,
    /// Inferences dispatched to chiplet queues.
    dispatched: u32,
    /// Inferences whose compute fully finished on this layer.
    completed: u32,
    /// Per-inference count of finished segments.
    segs_done: HashMap<u32, usize>,
    /// Earliest actual compute start per inference (for latency metrics).
    start_ns: HashMap<u32, TimeNs>,
    /// Latest compute completion per inference.
    done_ns: HashMap<u32, TimeNs>,
}

struct Instance {
    req: ModelRequest,
    model: NeuralModel,
    mapping: ModelMapping,
    results: Vec<Vec<ComputeResult>>,
    layers: Vec<LayerRuntime>,
    mapped_ns: TimeNs,
    /// Outstanding weight-load flows (ViT weight-stationary start-up).
    weight_flows: usize,
    /// inference index -> (flows outstanding into given layer).
    inflows: HashMap<(usize, u32), usize>,
    /// Comm span accounting: injection time per (dst layer, inference).
    comm_start: HashMap<(usize, u32), TimeNs>,
    comm_ns: Vec<f64>,
    inference_latency: Vec<u64>,
    inference_start: HashMap<u32, TimeNs>,
    finished: bool,
}

#[derive(Debug, Default)]
struct ChipletState {
    busy: bool,
    queue: VecDeque<(usize, usize, usize, u32)>, // (inst, layer, seg, inference)
    busy_ns: u64,
}

/// The co-simulation coordinator.
pub struct GlobalManager {
    hw: HardwareConfig,
    params: SimParams,
    topo: Topology,
    backend: Box<dyn ComputeBackend>,
}

impl GlobalManager {
    pub fn new(hw: HardwareConfig, params: SimParams) -> Self {
        let topo = Topology::build(&hw);
        let backend: Box<dyn ComputeBackend> = match params.compute_backend {
            ComputeBackendKind::Analytical => Box::new(ClassDispatchBackend::new()),
            ComputeBackendKind::Pjrt => Box::new(
                crate::compute::pjrt::PjrtImcBackend::open_default()
                    .expect("PJRT backend requires `make artifacts`"),
            ),
        };
        GlobalManager { hw, params, topo, backend }
    }

    /// Override the compute backend (dependency injection for tests).
    pub fn with_backend(mut self, backend: Box<dyn ComputeBackend>) -> Self {
        self.backend = backend;
        self
    }

    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    /// Run the co-simulation to completion.
    pub fn run(&mut self, workload: WorkloadConfig) -> anyhow::Result<SimReport> {
        let wall_start = Instant::now();
        let stream = WorkloadStream::from_kinds(
            &workload.kinds,
            self.params.inferences_per_model,
            workload.injection_interval_ns,
        );
        let mut net: Box<dyn NetworkSim> = match self.params.noc_fidelity {
            NocFidelity::Packet => Box::new(PacketEngine::new(self.topo.clone())),
            NocFidelity::Flit => Box::new(FlitEngine::new(self.topo.clone())),
        };
        let mut power = PowerTracker::new(self.hw.num_chiplets(), self.params.power_bin_ns);
        for c in 0..self.hw.num_chiplets() {
            power.set_baseline_mw(
                c,
                self.hw.chiplet_type(c).idle_mw + self.hw.link.router_static_mw,
            );
        }
        let mut ledger = MemoryLedger::new(&self.hw);
        let mut arb = ArbitrationQueue::new(self.params.age_threshold_ns);
        let mut chiplets: Vec<ChipletState> =
            (0..self.hw.num_chiplets()).map(|_| ChipletState::default()).collect();
        let mut instances: Vec<Instance> = Vec::new();
        let mut flow_of: HashMap<FlowId, (usize, usize, u32)> = HashMap::new();
        let mut outcomes: Vec<ModelOutcome> = Vec::new();
        let mut dropped: Vec<(usize, crate::workload::ModelKind)> = Vec::new();
        let mut queue: BinaryHeap<Reverse<QEntry>> = BinaryHeap::new();
        let mut seq: u64 = 0;
        let push = |queue: &mut BinaryHeap<Reverse<QEntry>>, seq: &mut u64, t: TimeNs, ev: Event| {
            *seq += 1;
            queue.push(Reverse(QEntry { t, seq: *seq, ev }));
        };
        for (i, req) in stream.requests.iter().enumerate() {
            push(&mut queue, &mut seq, req.arrival_ns, Event::Arrive(i));
        }
        let mut now: TimeNs = 0;
        let mut compute_energy = 0.0f64;
        let total_capacity = ledger.total_free();

        macro_rules! start_chiplet_if_idle {
            ($c:expr, $t:expr) => {{
                let cid = $c;
                if !chiplets[cid].busy {
                    if let Some((inst, layer, seg, inference)) = chiplets[cid].queue.pop_front() {
                        let r = instances[inst].results[layer][seg];
                        let lat = r.latency_ns.round().max(1.0) as TimeNs;
                        chiplets[cid].busy = true;
                        chiplets[cid].busy_ns += lat;
                        power.add_energy(cid, $t, lat, r.energy_pj);
                        compute_energy += r.energy_pj;
                        let lr = &mut instances[inst].layers[layer];
                        lr.start_ns.entry(inference).or_insert($t);
                        if layer == 0 {
                            instances[inst].inference_start.entry(inference).or_insert($t);
                        }
                        push(
                            &mut queue,
                            &mut seq,
                            $t + lat,
                            Event::ComputeDone { inst, layer, seg, inference },
                        );
                    }
                }
            }};
        }

        macro_rules! dispatch_ready {
            ($inst:expr, $layer:expr, $t:expr) => {{
                let inst = $inst;
                let layer = $layer;
                loop {
                    let can = {
                        let me = &instances[inst];
                        let lr = &me.layers[layer];
                        if lr.ready.is_empty() {
                            false
                        } else if !self.params.pipelined {
                            true // sequential execution: no overlap possible
                        } else if layer + 1 >= me.layers.len() {
                            true
                        } else {
                            // Double-buffering credit vs downstream stage.
                            lr.dispatched < me.layers[layer + 1].completed + PIPELINE_CREDITS
                        }
                    };
                    if !can {
                        break;
                    }
                    let inference = instances[inst].layers[layer].ready.pop_front().unwrap();
                    instances[inst].layers[layer].dispatched += 1;
                    let nsegs = instances[inst].mapping.layers[layer].len();
                    for s in 0..nsegs {
                        let cid = instances[inst].mapping.layers[layer][s].chiplet;
                        chiplets[cid].queue.push_back((inst, layer, s, inference));
                        start_chiplet_if_idle!(cid, $t);
                    }
                }
            }};
        }

        // Models are immutable per kind: build each once and clone cheaply
        // (arbitration probes used to rebuild the full layer table per
        // attempt — a measurable share of wall time, see EXPERIMENTS §Perf).
        let mut model_cache: HashMap<crate::workload::ModelKind, NeuralModel> = HashMap::new();
        let mut model_of = |kind: crate::workload::ModelKind| -> NeuralModel {
            model_cache.entry(kind).or_insert_with(|| NeuralModel::build(kind)).clone()
        };

        macro_rules! try_map_models {
            ($t:expr) => {{
                // Thermal-aware extension: rank chiplets by accumulated
                // dissipation (temperature proxy) when enabled.
                let heat: Option<Vec<f64>> = if self.params.thermal_aware_hops > 0.0 {
                    Some(
                        (0..self.hw.num_chiplets())
                            .map(|c| power.dynamic_energy_pj(c))
                            .collect(),
                    )
                } else {
                    None
                };
                let make_mapper = || {
                    let m = NearestNeighborMapper::new(&self.hw, &self.topo);
                    match &heat {
                        Some(h) => m.with_heat(h, self.params.thermal_aware_hops),
                        None => m,
                    }
                };
                loop {
                    let taken = arb.take_next_mappable($t, |req| {
                        let model = model_of(req.kind);
                        let mut probe = ledger.clone();
                        make_mapper().try_map(&model, &mut probe).is_some()
                    });
                    let Some(req) = taken else { break };
                    let model = model_of(req.kind);
                    let mapping =
                        make_mapper().try_map(&model, &mut ledger).expect("probe said it fits");
                    // Batched compute evaluation (one backend call per model).
                    let mut items = Vec::new();
                    for (li, layer) in mapping.layers.iter().enumerate() {
                        let _ = li;
                        for seg in layer {
                            items.push((self.hw.chiplet_type(seg.chiplet), seg.work));
                        }
                    }
                    let flat = self.backend.evaluate_batch(&items);
                    let mut results = Vec::with_capacity(mapping.layers.len());
                    let mut k = 0;
                    for layer in &mapping.layers {
                        let n = layer.len();
                        results.push(flat[k..k + n].to_vec());
                        k += n;
                    }
                    let nlayers = mapping.layers.len();
                    let inst_id = instances.len();
                    let mut inst = Instance {
                        req: req.clone(),
                        model,
                        mapping,
                        results,
                        layers: vec![LayerRuntime::default(); nlayers],
                        mapped_ns: $t,
                        weight_flows: 0,
                        inflows: HashMap::new(),
                        comm_start: HashMap::new(),
                        comm_ns: vec![0.0; req.inferences as usize],
                        inference_latency: Vec::new(),
                        inference_start: HashMap::new(),
                        finished: false,
                    };
                    // ViT-style weight-stationary start-up: stream each
                    // segment's weights from the nearest I/O chiplet.
                    if !self.hw.io_chiplets.is_empty() {
                        let mut flows = Vec::new();
                        for layer in &inst.mapping.layers {
                            for seg in layer {
                                let io = *self
                                    .hw
                                    .io_chiplets
                                    .iter()
                                    .min_by_key(|&&io| self.topo.hops(io, seg.chiplet))
                                    .unwrap();
                                flows.push(FlowSpec {
                                    src: io,
                                    dst: seg.chiplet,
                                    bytes: seg.mem_bytes,
                                });
                            }
                        }
                        inst.weight_flows = flows.len();
                        instances.push(inst);
                        for f in flows {
                            let id = net.inject(f, $t);
                            flow_of.insert(id, (inst_id, WEIGHT_LAYER, 0));
                        }
                    } else {
                        inst.layers[0].ready.push_back(0);
                        instances.push(inst);
                        dispatch_ready!(inst_id, 0, $t);
                    }
                }
                // Requests that can never fit even on an empty system are
                // dropped (and reported) instead of deadlocking the queue.
                if instances.iter().all(|i| i.finished) {
                    while let Some(req) = arb.take_next_mappable($t, |_| true) {
                        let model = model_of(req.kind);
                        let mut probe = MemoryLedger::new(&self.hw);
                        let mapper = NearestNeighborMapper::new(&self.hw, &self.topo);
                        if mapper.try_map(&model, &mut probe).is_none() {
                            log::warn!(
                                "dropping model {} ({}): needs {} bytes, system has {}",
                                req.id,
                                req.kind.name(),
                                model.total_weight_bytes(),
                                total_capacity
                            );
                            dropped.push((req.id, req.kind));
                        } else {
                            arb.push(req);
                            break;
                        }
                    }
                }
            }};
        }

        macro_rules! emit_layer_flows {
            ($inst:expr, $layer:expr, $inference:expr, $t:expr) => {{
                let inst = $inst;
                let layer = $layer;
                let inference = $inference;
                let (flows, expected) = {
                    let me = &instances[inst];
                    let out_bytes = me.model.layers[layer].out_bytes;
                    let srcs = &me.mapping.layers[layer];
                    let dsts = &me.mapping.layers[layer + 1];
                    let mut flows = Vec::new();
                    for s in srcs {
                        // Each destination segment needs the full activation
                        // tensor; each source produced `frac` of it.
                        let bytes = ((out_bytes as f64) * s.frac).ceil().max(1.0) as u64;
                        for d in dsts {
                            flows.push(FlowSpec { src: s.chiplet, dst: d.chiplet, bytes });
                        }
                    }
                    let n = flows.len();
                    (flows, n)
                };
                instances[inst].inflows.insert((layer + 1, inference), expected);
                instances[inst].comm_start.insert((layer + 1, inference), $t);
                for f in flows {
                    let id = net.inject(f, $t);
                    flow_of.insert(id, (inst, layer + 1, inference));
                }
            }};
        }

        macro_rules! finish_instance {
            ($inst:expr, $t:expr) => {{
                let inst = $inst;
                instances[inst].finished = true;
                ledger.release_mapping(&instances[inst].mapping);
                let me = &instances[inst];
                outcomes.push(ModelOutcome {
                    id: me.req.id,
                    kind: me.req.kind,
                    arrival_ns: me.req.arrival_ns,
                    mapped_ns: me.mapped_ns,
                    finished_ns: $t,
                    inferences: me.req.inferences,
                    inference_latency_ns: me.inference_latency.clone(),
                    // Pure compute span per inference: sum over layers of the
                    // slowest segment (segments of a layer run in parallel).
                    compute_ns: {
                        let per_inf: f64 = me
                            .results
                            .iter()
                            .map(|layer| {
                                layer.iter().map(|r| r.latency_ns).fold(0.0f64, f64::max)
                            })
                            .sum();
                        vec![per_inf; me.req.inferences as usize]
                    },
                    comm_ns: me.comm_ns.clone(),
                    segments: me.mapping.total_segments(),
                });
                push(&mut queue, &mut seq, $t, Event::TryMap);
            }};
        }

        // ------------------------------------------------------ main loop
        loop {
            let t_next = queue.peek().map(|Reverse(e)| e.t).unwrap_or(TimeNs::MAX);
            if net.has_active() {
                if let Some(c) = net.advance_until(t_next) {
                    now = now.max(c.time);
                    for (node, t, pj) in net.drain_energy_events() {
                        power.add_event(node, t, pj);
                    }
                    let Some((inst, layer, inference)) = flow_of.remove(&c.id) else {
                        continue;
                    };
                    if instances[inst].finished {
                        continue;
                    }
                    if layer == WEIGHT_LAYER {
                        instances[inst].weight_flows -= 1;
                        if instances[inst].weight_flows == 0 {
                            instances[inst].layers[0].ready.push_back(0);
                            dispatch_ready!(inst, 0, c.time);
                        }
                    } else {
                        let left = instances[inst].inflows.get_mut(&(layer, inference)).unwrap();
                        *left -= 1;
                        if *left == 0 {
                            instances[inst].inflows.remove(&(layer, inference));
                            if let Some(t0) =
                                instances[inst].comm_start.remove(&(layer, inference))
                            {
                                let span = (c.time - t0) as f64;
                                if let Some(slot) =
                                    instances[inst].comm_ns.get_mut(inference as usize)
                                {
                                    *slot += span;
                                }
                            }
                            instances[inst].layers[layer].ready.push_back(inference);
                            dispatch_ready!(inst, layer, c.time);
                        }
                    }
                    continue;
                }
            }
            let Some(Reverse(entry)) = queue.pop() else {
                break;
            };
            now = now.max(entry.t);
            if self.params.max_sim_time_ns > 0 && now > self.params.max_sim_time_ns {
                log::warn!("max_sim_time reached at {now} ns; truncating run");
                break;
            }
            match entry.ev {
                Event::Arrive(i) => {
                    arb.push(stream.requests[i].clone());
                    try_map_models!(entry.t);
                }
                Event::TryMap => {
                    try_map_models!(entry.t);
                }
                Event::ComputeDone { inst, layer, seg, inference } => {
                    let cid = instances[inst].mapping.layers[layer][seg].chiplet;
                    chiplets[cid].busy = false;
                    start_chiplet_if_idle!(cid, entry.t);
                    let nsegs = instances[inst].mapping.layers[layer].len();
                    let done = {
                        let lr = &mut instances[inst].layers[layer];
                        let cnt = lr.segs_done.entry(inference).or_insert(0);
                        *cnt += 1;
                        *cnt == nsegs
                    };
                    if !done {
                        continue;
                    }
                    // Whole layer finished this inference.
                    {
                        let lr = &mut instances[inst].layers[layer];
                        lr.segs_done.remove(&inference);
                        lr.completed += 1;
                        lr.done_ns.insert(inference, entry.t);
                    }
                    let nlayers = instances[inst].layers.len();
                    let n_inf = instances[inst].req.inferences;
                    // Free a downstream credit for the upstream stage.
                    if self.params.pipelined && layer > 0 {
                        dispatch_ready!(inst, layer - 1, entry.t);
                    }
                    // Pipelined: layer 0 chains itself to the next inference.
                    if self.params.pipelined && layer == 0 && inference + 1 < n_inf {
                        instances[inst].layers[0].ready.push_back(inference + 1);
                        dispatch_ready!(inst, 0, entry.t);
                    }
                    if layer + 1 < nlayers {
                        emit_layer_flows!(inst, layer, inference, entry.t);
                    } else {
                        // Inference complete.
                        let start = *instances[inst]
                            .inference_start
                            .get(&inference)
                            .unwrap_or(&instances[inst].mapped_ns);
                        instances[inst].inference_latency.push(entry.t - start);
                        if !self.params.pipelined && inference + 1 < n_inf {
                            instances[inst].layers[0].ready.push_back(inference + 1);
                            dispatch_ready!(inst, 0, entry.t);
                        }
                        if instances[inst].inference_latency.len() == n_inf as usize {
                            finish_instance!(inst, entry.t);
                        }
                    }
                }
            }
        }

        for (node, t, pj) in net.drain_energy_events() {
            power.add_event(node, t, pj);
        }
        let span_ns = now;
        let link_util =
            crate::noc::LinkUtilization::from_busy(&net.link_busy_ns(), span_ns);
        let hi = span_ns.saturating_sub(self.params.cooldown_ns).max(self.params.warmup_ns);
        Ok(SimReport {
            outcomes,
            dropped,
            span_ns,
            power,
            chiplet_busy_ns: chiplets.iter().map(|c| c.busy_ns).collect(),
            comm_energy_pj: net.comm_energy_pj(),
            compute_energy_pj: compute_energy,
            noc_work: net.work_done(),
            link_util,
            wall_ns: wall_start.elapsed().as_nanos(),
            stats_window: (self.params.warmup_ns, hi),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::ModelKind;

    fn small_params() -> SimParams {
        SimParams {
            inferences_per_model: 2,
            warmup_ns: 0,
            cooldown_ns: 0,
            ..SimParams::default()
        }
    }

    #[test]
    fn single_model_completes() {
        let hw = HardwareConfig::homogeneous_mesh(4, 4);
        let mut gm = GlobalManager::new(hw, small_params());
        let report = gm.run(WorkloadConfig::single(ModelKind::ResNet18)).unwrap();
        assert_eq!(report.outcomes.len(), 1);
        assert_eq!(report.outcomes[0].inference_latency_ns.len(), 2);
        assert!(report.outcomes[0].mean_latency_ns() > 0.0);
        assert!(report.dropped.is_empty());
    }

    #[test]
    fn pipelined_is_not_slower_in_throughput() {
        let hw = HardwareConfig::homogeneous_mesh(4, 4);
        let mut p1 = small_params();
        p1.inferences_per_model = 8;
        let mut p2 = p1.clone();
        p2.pipelined = true;
        let r_seq = GlobalManager::new(hw.clone(), p1)
            .run(WorkloadConfig::single(ModelKind::ResNet18))
            .unwrap();
        let r_pipe = GlobalManager::new(hw, p2)
            .run(WorkloadConfig::single(ModelKind::ResNet18))
            .unwrap();
        // Pipelining overlaps layers: total completion time must shrink.
        assert!(
            r_pipe.outcomes[0].finished_ns < r_seq.outcomes[0].finished_ns,
            "pipe {} !< seq {}",
            r_pipe.outcomes[0].finished_ns,
            r_seq.outcomes[0].finished_ns
        );
    }

    #[test]
    fn oversized_model_is_dropped_not_deadlocked() {
        let hw = HardwareConfig::homogeneous_mesh(2, 2); // 8 MiB total
        let mut gm = GlobalManager::new(hw, small_params());
        let report = gm.run(WorkloadConfig::single(ModelKind::AlexNet)).unwrap();
        assert_eq!(report.outcomes.len(), 0);
        assert_eq!(report.dropped.len(), 1);
    }

    #[test]
    fn stream_of_models_all_finish() {
        let hw = HardwareConfig::homogeneous_mesh(8, 8);
        let mut params = small_params();
        params.pipelined = true;
        let mut gm = GlobalManager::new(hw, params);
        let wl = WorkloadConfig::from_kinds(&[
            ModelKind::ResNet18,
            ModelKind::AlexNet,
            ModelKind::ResNet34,
            ModelKind::ResNet18,
        ]);
        let report = gm.run(wl).unwrap();
        assert_eq!(report.outcomes.len() + report.dropped.len(), 4);
        assert!(report.outcomes.len() >= 3);
        // Power was tracked.
        assert!(report.power.num_bins() > 0);
        assert!(report.comm_energy_pj > 0.0);
        assert!(report.compute_energy_pj > 0.0);
    }

    #[test]
    fn contention_from_parallel_models_inflates_latency() {
        // One ResNet18 alone vs four running concurrently on the same mesh.
        let hw = HardwareConfig::homogeneous_mesh(10, 10);
        let mut params = small_params();
        params.pipelined = true;
        params.inferences_per_model = 4;
        let solo = GlobalManager::new(hw.clone(), params.clone())
            .run(WorkloadConfig::single(ModelKind::ResNet18))
            .unwrap();
        let busy = GlobalManager::new(hw, params)
            .run(WorkloadConfig::from_kinds(&[ModelKind::ResNet18; 4]))
            .unwrap();
        let lat_solo = solo.mean_latency_of(ModelKind::ResNet18).unwrap();
        let lat_busy = busy.mean_latency_of(ModelKind::ResNet18).unwrap();
        assert!(
            lat_busy > lat_solo,
            "contention must inflate latency: busy {lat_busy} !> solo {lat_solo}"
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let hw = HardwareConfig::homogeneous_mesh(6, 6);
        let run = || {
            GlobalManager::new(hw.clone(), small_params())
                .run(WorkloadConfig::from_kinds(&[ModelKind::ResNet18, ModelKind::AlexNet]))
                .unwrap()
        };
        let a = run();
        let b = run();
        assert_eq!(a.span_ns, b.span_ns);
        let la: Vec<_> = a.outcomes.iter().map(|o| o.inference_latency_ns.clone()).collect();
        let lb: Vec<_> = b.outcomes.iter().map(|o| o.inference_latency_ns.clone()).collect();
        assert_eq!(la, lb);
    }
}
