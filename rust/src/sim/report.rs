//! Simulation results: per-model outcomes, per-kind aggregates, power.

use std::collections::BTreeMap;

use crate::dtm::DtmReport;
use crate::noc::{LinkUtilization, TenantComm};
use crate::power::PowerTracker;
use crate::util::benchkit::fmt_ns;
use crate::workload::ModelKind;
use crate::TimeNs;

/// Outcome of one model instance.
#[derive(Debug, Clone)]
pub struct ModelOutcome {
    pub id: usize,
    pub kind: ModelKind,
    /// Owning tenant in a multi-tenant mix (0 for single-tenant runs).
    pub tenant: usize,
    pub arrival_ns: TimeNs,
    pub mapped_ns: TimeNs,
    pub finished_ns: TimeNs,
    pub inferences: u32,
    /// Per-inference end-to-end latency (layer-0 compute start -> last
    /// layer compute done), ns.
    pub inference_latency_ns: Vec<u64>,
    /// Per-inference pure compute span (sum over layers of slowest-segment
    /// latency), ns.
    pub compute_ns: Vec<f64>,
    /// Per-inference communication span (sum over layer boundaries of
    /// injection -> all-flows-arrived), ns.
    pub comm_ns: Vec<f64>,
    /// Total segments in the mapping (occupancy metric).
    pub segments: usize,
    /// Latency breakdown (components sum exactly to
    /// `finished_ns - arrival_ns`).  Populated only when a flight
    /// recorder with breakdown enabled is installed; deliberately
    /// excluded from [`SimReport::fingerprint`] so a tracing-off run is
    /// bitwise-identical to a never-instrumented one.
    pub breakdown: Option<crate::trace::LatencyBreakdown>,
}

impl ModelOutcome {
    pub fn mean_latency_ns(&self) -> f64 {
        mean_u(&self.inference_latency_ns)
    }
}

fn mean_u(xs: &[u64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<u64>() as f64 / xs.len() as f64
    }
}

fn mean_f(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Aggregate statistics per model kind.
#[derive(Debug, Clone, Default)]
pub struct KindStats {
    pub instances: usize,
    pub inferences: usize,
    pub mean_latency_ns: f64,
    pub mean_compute_ns: f64,
    pub mean_comm_ns: f64,
}

/// End-of-run thermal roll-up (populated when the simulation was built
/// with a `ThermalSpec` other than `Off`).
#[derive(Debug, Clone)]
pub struct ThermalSummary {
    /// Which solver produced it ("pjrt-aot" or "native").
    pub solver: &'static str,
    /// Transient steps integrated.
    pub steps: usize,
    pub hottest_c: f64,
    pub coolest_c: f64,
    pub spread_k: f64,
}

/// Full result of a co-simulation run.
#[derive(Debug)]
pub struct SimReport {
    pub outcomes: Vec<ModelOutcome>,
    /// Models that could never be mapped (too large for the system).
    pub dropped: Vec<(usize, ModelKind)>,
    /// Total simulated span, ns.
    pub span_ns: TimeNs,
    pub power: PowerTracker,
    /// Per-chiplet compute-busy time, ns.
    pub chiplet_busy_ns: Vec<u64>,
    /// Total NoI dynamic energy, pJ.
    pub comm_energy_pj: f64,
    /// Total compute dynamic energy, pJ.
    pub compute_energy_pj: f64,
    /// Bytes × hops moved through the NoI (throughput metric).
    pub noc_work: u64,
    /// Per-link NoI utilization over the run (bottleneck analysis).
    pub link_util: LinkUtilization,
    /// NoI traffic attributed per tenant (dense by tenant index; a
    /// single-tenant run books everything under tenant 0).
    pub tenant_comm: Vec<TenantComm>,
    /// Wall-clock runtime of the simulation itself, ns.
    pub wall_ns: u128,
    /// Statistics window applied (warmup/cooldown trimming).
    pub stats_window: (TimeNs, TimeNs),
    /// End-of-run thermal summary (None when thermal coupling was off).
    pub thermal: Option<ThermalSummary>,
    /// Closed-loop DTM results (populated by `ThermalSpec::InLoop`).
    pub dtm: Option<DtmReport>,
    /// Fault-injection results (populated when a non-empty `--faults`
    /// plan was armed).  Participates in [`fingerprint`](Self::fingerprint)
    /// so fault runs are determinism-checked like everything else.
    pub fault: Option<crate::fault::FaultReport>,
    /// Host-side self-profile of the simulator (populated when
    /// [`crate::prof`] collection is enabled, e.g. via `--profile`).
    /// Like `wall_ns` and the latency breakdown, it is host-timing
    /// data and therefore excluded from [`fingerprint`](Self::fingerprint).
    pub profile: Option<crate::prof::ProfileReport>,
}

impl SimReport {
    /// Per-kind aggregates over the statistics window: inferences whose
    /// model instance was mapped inside [warmup, span-cooldown] (falls
    /// back to all instances if the window would be empty).
    pub fn by_kind(&self) -> BTreeMap<&'static str, KindStats> {
        let (lo, hi) = self.stats_window;
        let in_window: Vec<&ModelOutcome> = {
            let w: Vec<&ModelOutcome> = self
                .outcomes
                .iter()
                .filter(|o| o.mapped_ns >= lo && o.finished_ns <= hi)
                .collect();
            if w.is_empty() {
                self.outcomes.iter().collect()
            } else {
                w
            }
        };
        let mut map: BTreeMap<&'static str, KindStats> = BTreeMap::new();
        for o in in_window {
            let e = map.entry(o.kind.name()).or_default();
            e.instances += 1;
            e.inferences += o.inference_latency_ns.len();
            e.mean_latency_ns += o.inference_latency_ns.iter().sum::<u64>() as f64;
            e.mean_compute_ns += o.compute_ns.iter().sum::<f64>();
            e.mean_comm_ns += o.comm_ns.iter().sum::<f64>();
        }
        for s in map.values_mut() {
            let n = s.inferences.max(1) as f64;
            s.mean_latency_ns /= n;
            s.mean_compute_ns /= n;
            s.mean_comm_ns /= n;
        }
        map
    }

    /// Mean end-to-end inference latency for one kind, ns.
    pub fn mean_latency_of(&self, kind: ModelKind) -> Option<f64> {
        self.by_kind().get(kind.name()).map(|s| s.mean_latency_ns)
    }

    /// Average chiplet compute utilization over the run.
    pub fn mean_utilization(&self) -> f64 {
        if self.span_ns == 0 {
            return 0.0;
        }
        let busy: u64 = self.chiplet_busy_ns.iter().sum();
        busy as f64 / (self.span_ns as f64 * self.chiplet_busy_ns.len() as f64)
    }

    /// Human-readable summary.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "simulated {} models ({} dropped) over {}  [wall {:.2} s]\n",
            self.outcomes.len(),
            self.dropped.len(),
            fmt_ns(self.span_ns as f64),
            self.wall_ns as f64 / 1e9,
        );
        s.push_str(&format!(
            "energy: compute {:.3} mJ, comm {:.3} mJ;  mean chiplet utilization {:.1}%\n",
            self.compute_energy_pj / 1e9,
            self.comm_energy_pj / 1e9,
            self.mean_utilization() * 100.0
        ));
        if let Some(th) = &self.thermal {
            s.push_str(&format!(
                "thermal ({}, {} steps): hottest {:.2} °C, coolest {:.2} °C, spread {:.2} K\n",
                th.solver, th.steps, th.hottest_c, th.coolest_c, th.spread_k
            ));
        }
        if let Some(d) = &self.dtm {
            s.push_str(&d.summary());
        }
        if let Some(f) = &self.fault {
            s.push_str(&f.summary());
        }
        for (kind, st) in self.by_kind() {
            s.push_str(&format!(
                "  {kind:<10} x{:<3} mean inference latency {:>12}  (compute {:>12}, comm {:>12})\n",
                st.instances,
                fmt_ns(st.mean_latency_ns),
                fmt_ns(st.mean_compute_ns),
                fmt_ns(st.mean_comm_ns),
            ));
        }
        s
    }

    pub fn mean_compute_comm_of(&self, kind: ModelKind) -> Option<(f64, f64)> {
        self.by_kind().get(kind.name()).map(|s| (s.mean_compute_ns, s.mean_comm_ns))
    }

    /// Stable digest of the run for determinism checks: two runs are
    /// byte-identical iff their fingerprints are equal.  Floats are
    /// compared via their bit patterns — no rounding slack.
    pub fn fingerprint(&self) -> String {
        use std::fmt::Write;
        let mut s = String::new();
        let _ = write!(
            s,
            "span={};comm={:016x};compute={:016x};work={}",
            self.span_ns,
            self.comm_energy_pj.to_bits(),
            self.compute_energy_pj.to_bits(),
            self.noc_work
        );
        for o in &self.outcomes {
            let _ = write!(
                s,
                ";{}:{}:a{}:m{}:f{}",
                o.id,
                o.kind.name(),
                o.arrival_ns,
                o.mapped_ns,
                o.finished_ns
            );
            for &l in &o.inference_latency_ns {
                let _ = write!(s, ",{l}");
            }
        }
        for (id, kind) in &self.dropped {
            let _ = write!(s, ";drop{}:{}", id, kind.name());
        }
        if let Some(d) = &self.dtm {
            let _ = write!(s, ";dtm[{}]", d.fingerprint());
        }
        if let Some(f) = &self.fault {
            let _ = write!(s, ";fault[{}]", f.fingerprint());
        }
        s
    }
}

#[allow(dead_code)]
fn _mean_helpers_used(xs: &[f64]) -> f64 {
    mean_f(xs)
}
