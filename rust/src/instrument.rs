//! Shared run instrumentation for the CLI: one parser for the flag
//! clusters every serving subcommand accepts, and one attach/export
//! surface over [`Simulation`] / fleet runs.
//!
//! Before this module, `--trace*`, `--profile*`, `--faults*`, and
//! `--threads` handling was copy-pasted across the `traffic`, `mix`,
//! `fleet`, `batch`, `trace`, and `profile` subcommands, each with its
//! own slightly different plumbing.  [`RunOptions::from_args`] parses
//! the whole cluster once (all old flags keep working, spelled exactly
//! as before), and [`Instrumentation`] owns the lifecycle:
//!
//! 1. construction arms the self-profiler if `--profile` was given;
//! 2. [`attach`](Instrumentation::attach) wires a built [`Simulation`]
//!    — execution spec (`--threads`), CLI fault plan (replacing a
//!    preset's), and the flight recorder (first board only, so solo
//!    interference baselines never reset the shared recorder);
//! 3. the `write_*`/`finish_*` methods export trace JSON, the
//!    [`FaultReport`], and the profile (attached report preferred,
//!    snapshot fallback for sweeps whose probes share one collection).

use std::sync::Mutex;
use std::time::Instant;

use crate::fault::{FaultPlan, FaultReport};
use crate::par::ExecSpec;
use crate::prof::ProfileReport;
use crate::sim::Simulation;
use crate::trace::{merge_export, TraceCategories, TraceConfig, TraceHandle, TraceRecorder};
use crate::util::cli::Args;
use crate::util::json::Value;

/// The parsed `--threads` / `--trace*` / `--profile*` / `--faults*`
/// flag cluster, shared by every run-shaped subcommand.
#[derive(Debug, Clone, Default)]
pub struct RunOptions {
    /// `--threads N`: `None` when the flag is absent (subcommands pick
    /// their own default: sequential engines for a single run, all
    /// cores for fleet/batch worker pools).
    pub threads: Option<usize>,
    /// `--trace` / `--trace-filter CATS`: flight-recorder config, or
    /// `None` when tracing is off (hook sites then cost one pointer
    /// check per event).
    pub trace: Option<TraceConfig>,
    /// `--trace-out FILE.json` (default: results dir).
    pub trace_out: Option<String>,
    /// `--profile` / `--profile-out`: self-profile the simulator.
    pub profile: bool,
    /// `--profile-out FILE.json` (default: results dir).
    pub profile_out: Option<String>,
    /// `--faults PLAN`: a parsed fault plan.  On a scenario run it
    /// *replaces* the scenario's built-in one.
    pub faults: Option<FaultPlan>,
    /// `--faults-out FILE.json`: write the run's [`FaultReport`].
    pub faults_out: Option<String>,
}

impl RunOptions {
    /// Parse the shared cluster from CLI args.  Flags keep their exact
    /// historical spelling and semantics; errors carry the same
    /// actionable context the per-subcommand parsers used to print.
    pub fn from_args(args: &Args) -> anyhow::Result<RunOptions> {
        let threads = match args.get("threads") {
            Some(_) => Some(args.get_usize("threads", 0)?),
            None => None,
        };
        let trace = if args.flag("trace")
            || args.get("trace-filter").is_some()
            || args.get("trace-out").is_some()
        {
            let mut cfg = TraceConfig::default();
            if let Some(f) = args.get("trace-filter") {
                cfg.categories = TraceCategories::parse(f)?;
            }
            Some(cfg)
        } else {
            None
        };
        let faults = match args.get("faults") {
            None => None,
            Some(spec) => Some(FaultPlan::parse(spec).map_err(|e| {
                anyhow::anyhow!("--faults: {e:#} (`chipsim faults` has the grammar)")
            })?),
        };
        Ok(RunOptions {
            threads,
            trace,
            trace_out: args.get("trace-out").map(str::to_string),
            profile: args.flag("profile") || args.get("profile-out").is_some(),
            profile_out: args.get("profile-out").map(str::to_string),
            faults,
            faults_out: args.get("faults-out").map(str::to_string),
        })
    }

    /// The [`ExecSpec`] this run asked for: `--threads N` maps to an
    /// `N`-worker spec (`0` = all cores), an absent flag to the
    /// sequential default.
    pub fn exec(&self) -> ExecSpec {
        match self.threads {
            Some(n) => ExecSpec::threads(n),
            None => ExecSpec::default(),
        }
    }

    /// Worker count for sites whose unit of parallelism is whole
    /// boards/scenarios (fleet epochs, batch sweeps), where the
    /// historical default is all cores.
    pub fn pool_threads(&self) -> usize {
        self.threads.unwrap_or(0)
    }

    /// Finish parsing and start the instrumentation lifecycle (arms the
    /// profiler, starts the wall-clock).
    pub fn instrument(self) -> Instrumentation {
        Instrumentation::new(self)
    }
}

/// One attach/export surface for a subcommand's run: owns the parsed
/// [`RunOptions`], the profile wall-clock, and every adopted trace
/// recorder handle.  See the module docs for the lifecycle.
pub struct Instrumentation {
    opts: RunOptions,
    started: Instant,
    tracers: Mutex<Vec<TraceHandle>>,
}

impl Instrumentation {
    /// Begin the lifecycle: arms the global self-profiler when
    /// `--profile` was requested, so every scope and counter hook from
    /// here on records.
    pub fn new(opts: RunOptions) -> Instrumentation {
        if opts.profile {
            crate::prof::enable();
        }
        Instrumentation { opts, started: Instant::now(), tracers: Mutex::new(Vec::new()) }
    }

    /// The parsed options (for per-subcommand decisions, e.g. rejecting
    /// `--trace` under `--sweep`).
    pub fn options(&self) -> &RunOptions {
        &self.opts
    }

    /// Mutable options: subcommands with preset fallbacks (mix picks up
    /// a scenario-carried fault plan when `--faults` is absent) adjust
    /// the cluster before attaching.
    pub fn options_mut(&mut self) -> &mut RunOptions {
        &mut self.opts
    }

    /// Was `--profile` requested?
    pub fn profiling(&self) -> bool {
        self.opts.profile
    }

    /// Wire a built [`Simulation`]: execution spec, CLI fault plan
    /// (replacing any preset plan already on the board), and the flight
    /// recorder.  Only the *first* attached board records a trace —
    /// mix solo baselines and sweep probes run untraced, exactly as the
    /// per-subcommand plumbing behaved.
    pub fn attach(&self, sim: &mut Simulation) {
        sim.set_exec(self.opts.exec());
        if let Some(plan) = &self.opts.faults {
            sim.set_fault_plan(Some(plan.clone()));
        }
        if let Some(cfg) = &self.opts.trace {
            let mut slot = self.tracers.lock().expect("tracer slot");
            if slot.is_empty() {
                slot.push(sim.set_trace(cfg.clone()));
            }
        }
    }

    /// Adopt externally created recorder handles (a fleet attaches one
    /// per replica itself); they join the merged export.
    pub fn adopt_tracers(&self, handles: &[TraceHandle]) {
        self.tracers.lock().expect("tracer slot").extend(handles.iter().cloned());
    }

    /// Export every adopted trace as one Chrome trace-event document to
    /// `--trace-out` (or the results dir under `default_name`).  No-op
    /// when tracing was off.
    pub fn export_trace(&self, default_name: &str) -> anyhow::Result<()> {
        let tracers = self.tracers.lock().expect("tracer slot");
        if tracers.is_empty() {
            return Ok(());
        }
        let recs: Vec<_> = tracers.iter().map(|h| h.lock().expect("trace lock")).collect();
        let refs: Vec<&TraceRecorder> = recs.iter().map(|g| &**g).collect();
        write_trace_doc(&merge_export(&refs), self.opts.trace_out.as_deref(), default_name)
    }

    /// Write the run's [`FaultReport`] to `--faults-out`.  A run
    /// without a fired fault has no report — that is an error, not a
    /// silent no-op, so CI gates can't pass vacuously.
    pub fn write_fault_report(&self, fault: Option<&FaultReport>) -> anyhow::Result<()> {
        write_fault_report(self.opts.faults_out.as_deref(), fault)
    }

    /// Close out `--profile`: prefer the profile attached to the run's
    /// report (its wall-clock brackets exactly the simulated region);
    /// fall back to a fresh snapshot over this instrumentation's own
    /// wall time (sweeps and batches, whose many runs share one
    /// collection).  No-op when profiling was off.
    pub fn finish_profile(
        &self,
        attached: Option<&ProfileReport>,
        default_name: &str,
    ) -> anyhow::Result<()> {
        if !self.opts.profile {
            return Ok(());
        }
        let fallback = crate::prof::snapshot(self.started.elapsed().as_nanos() as u64);
        write_profile(
            attached.or(fallback.as_ref()),
            self.opts.profile_out.as_deref(),
            default_name,
        )
    }
}

/// Write an exported trace document to `out`, or into the results dir
/// under `default_name`.
pub fn write_trace_doc(doc: &Value, out: Option<&str>, default_name: &str) -> anyhow::Result<()> {
    match out {
        Some(path) => {
            std::fs::write(path, crate::util::json::to_string_pretty(doc))?;
            println!("trace written to {path} (load in Perfetto / chrome://tracing)");
        }
        None => {
            let path = crate::metrics::write_json(default_name, doc)?;
            println!(
                "trace written to {} (load in Perfetto / chrome://tracing)",
                path.display()
            );
        }
    }
    Ok(())
}

/// Write a [`FaultReport`] to `out` (see
/// [`Instrumentation::write_fault_report`]); no-op when `out` is `None`.
pub fn write_fault_report(out: Option<&str>, fault: Option<&FaultReport>) -> anyhow::Result<()> {
    let Some(path) = out else { return Ok(()) };
    let f = fault.ok_or_else(|| {
        anyhow::anyhow!(
            "--faults-out: the run produced no FaultReport (arm a plan with --faults \
             or a fault-* scenario whose events fire inside the horizon)"
        )
    })?;
    std::fs::write(path, crate::util::json::to_string_pretty(&f.to_json()))?;
    println!("fault report written to {path}");
    Ok(())
}

/// Print a collected profile and write its JSON to `out` (or the
/// results dir under `default_name`), plus an inferno-compatible
/// `.collapsed` sibling for flamegraph rendering.
pub fn write_profile(
    profile: Option<&ProfileReport>,
    out: Option<&str>,
    default_name: &str,
) -> anyhow::Result<()> {
    let Some(p) = profile else {
        println!(
            "self-profiling requested, but no profile was collected (built without \
             the `prof` feature?)"
        );
        return Ok(());
    };
    print!("{}", p.render());
    println!("{}", p.summary());
    let json_path = match out {
        Some(path) => {
            std::fs::write(path, crate::util::json::to_string_pretty(&p.to_json()))?;
            std::path::PathBuf::from(path)
        }
        None => crate::metrics::write_json(default_name, &p.to_json())?,
    };
    let collapsed_path = json_path.with_extension("collapsed");
    std::fs::write(&collapsed_path, p.collapsed())?;
    println!(
        "profile written to {} (collapsed stacks: {} — render with inferno-flamegraph \
         or flamegraph.pl)",
        json_path.display(),
        collapsed_path.display()
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_args_parses_the_whole_cluster() {
        let args = Args::parse(
            [
                "--threads", "8", "--trace", "--trace-filter", "request,noi", "--profile-out",
                "p.json", "--faults", "link:0-1@1ms", "--faults-out", "f.json",
            ]
            .iter()
            .map(|s| s.to_string()),
            &["trace", "profile"],
        );
        let opts = RunOptions::from_args(&args).unwrap();
        assert_eq!(opts.threads, Some(8));
        assert_eq!(opts.exec(), ExecSpec::threads(8));
        assert_eq!(opts.pool_threads(), 8);
        assert!(opts.trace.is_some());
        assert!(opts.profile, "--profile-out implies --profile");
        assert_eq!(opts.profile_out.as_deref(), Some("p.json"));
        assert!(opts.faults.is_some());
        assert_eq!(opts.faults_out.as_deref(), Some("f.json"));
    }

    #[test]
    fn absent_flags_mean_sequential_run_and_all_core_pools() {
        let opts = RunOptions::from_args(&Args::default()).unwrap();
        assert_eq!(opts.threads, None);
        assert_eq!(opts.exec(), ExecSpec::default());
        assert!(!opts.exec().is_parallel());
        assert_eq!(opts.pool_threads(), 0);
        assert!(opts.trace.is_none() && opts.faults.is_none() && !opts.profile);
    }

    #[test]
    fn bad_fault_plans_keep_their_actionable_context() {
        let args = Args::parse(
            ["--faults", "gremlin:0@1ms"].iter().map(|s| s.to_string()),
            &[],
        );
        let err = RunOptions::from_args(&args).unwrap_err();
        assert!(format!("{err:#}").contains("chipsim faults"), "{err:#}");
    }

    #[test]
    fn fault_report_without_a_fired_fault_is_an_error() {
        assert!(write_fault_report(Some("/dev/null"), None).is_err());
        assert!(write_fault_report(None, None).is_ok());
    }
}
