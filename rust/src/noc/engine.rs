//! Event-driven packet-level NoI engine (default fidelity).
//!
//! Messages are segmented into packets of [`PACKET_FLITS`] flits.  Each
//! packet traverses its route hop-by-hop with virtual-cut-through
//! semantics: at every link it waits for the link to drain earlier
//! packets (per-link FIFO, global-time order => round-robin-ish fairness
//! between flows sharing a link), occupies the link for its serialization
//! time, and arrives at the next router after the router pipeline delay.
//!
//! Contention therefore emerges exactly where the paper requires it
//! (§III-D): concurrent flows from different DNN models queue on shared
//! links, and per-flow latency inflates with utilization.  The flit-level
//! engine (`flit.rs`) validates this model on small cases.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};

use super::topology::Topology;
use super::{EnergyLog, FlowCompletion, FlowId, FlowSpec, FlowStats, LinkTraceEvent, NetworkSim};
use crate::TimeNs;

/// Flits per packet (HeteroGarnet-style message segmentation).
pub const PACKET_FLITS: u64 = 16;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct PacketEvent {
    /// Arrival time of the packet head at `node`, ns.
    time: TimeNs,
    /// Deterministic FIFO tie-break.
    seq: u64,
    flow: FlowId,
    /// Payload bytes of this packet.
    bytes: u64,
    /// Index into the flow's path: the next link to take from `node`.
    hop: usize,
}

impl Ord for PacketEvent {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.time, self.seq).cmp(&(other.time, other.seq))
    }
}
impl PartialOrd for PacketEvent {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

#[derive(Debug)]
struct FlowState {
    spec: FlowSpec,
    injected_ns: TimeNs,
    path: Vec<usize>,
    packets_left: u64,
    last_arrival: TimeNs,
}

/// The packet-granularity network engine.
///
/// Flow ids are sequential, so flow state lives in flat `Vec`s instead of
/// hash maps — the per-event lookup was a measurable cost (§Perf).
pub struct PacketEngine {
    topo: Topology,
    /// Earliest time each link is free again.
    link_free: Vec<TimeNs>,
    /// Cumulative serialization (busy) time per link, ns.
    link_busy: Vec<TimeNs>,
    events: BinaryHeap<Reverse<PacketEvent>>,
    flows: Vec<Option<FlowState>>,
    active_flows: usize,
    finished: HashMap<FlowId, FlowStats>,
    /// Completions discovered but not yet reported via advance_until.
    completions: BinaryHeap<Reverse<(TimeNs, FlowId)>>,
    next_flow_id: FlowId,
    next_seq: u64,
    /// (node, time, pj) dynamic-energy events, coalesced per power bin
    /// (drained by the power tracker).
    energy: EnergyLog,
    /// Byte-hops processed (throughput metric for perf benches).
    work: u64,
    /// Current simulated network time (monotone).
    now: TimeNs,
    /// Cached per-hop router latency in ns (constant per topology).
    hop_ns: TimeNs,
    /// Cached serialization time of a full packet per link, ns.
    full_pkt_ser: Vec<TimeNs>,
    /// Cached full-packet payload bytes per link.
    full_pkt_bytes: Vec<u64>,
    /// Per-packet-hop occupancy log for the flight recorder; `None`
    /// (the default) keeps tracing entirely off the hot path.
    link_trace: Option<Vec<LinkTraceEvent>>,
}

impl PacketEngine {
    pub fn new(topo: Topology) -> Self {
        let nlinks = topo.links.len();
        let nnodes = topo.num_nodes;
        let hop_ns = topo.hop_ns().round() as TimeNs;
        let full_pkt_bytes: Vec<u64> =
            topo.links.iter().map(|l| PACKET_FLITS * l.width_bytes).collect();
        let full_pkt_ser: Vec<TimeNs> = (0..nlinks)
            .map(|l| (topo.ser_ns(l, full_pkt_bytes[l]).round() as TimeNs).max(1))
            .collect();
        PacketEngine {
            hop_ns,
            full_pkt_ser,
            full_pkt_bytes,
            topo,
            link_free: vec![0; nlinks],
            link_busy: vec![0; nlinks],
            events: BinaryHeap::new(),
            flows: Vec::new(),
            active_flows: 0,
            finished: HashMap::new(),
            completions: BinaryHeap::new(),
            next_flow_id: 0,
            next_seq: 0,
            energy: EnergyLog::new(nnodes),
            work: 0,
            now: 0,
            link_trace: None,
        }
    }

    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    fn seq(&mut self) -> u64 {
        self.next_seq += 1;
        self.next_seq
    }

    /// Process a single packet event: acquire the next link, schedule the
    /// arrival at the following node (or finish the packet).
    fn step_event(&mut self, ev: PacketEvent) {
        self.now = self.now.max(ev.time);
        let flow = self.flows[ev.flow as usize].as_mut().expect("event for unknown flow");
        if ev.hop == flow.path.len() {
            // Head of this packet reached the destination NI.
            flow.packets_left -= 1;
            flow.last_arrival = flow.last_arrival.max(ev.time);
            if flow.packets_left == 0 {
                let stats = FlowStats {
                    spec: flow.spec,
                    injected_ns: flow.injected_ns,
                    completed_ns: flow.last_arrival,
                    hops: flow.path.len() as u32,
                };
                let id = ev.flow;
                self.flows[id as usize] = None;
                self.active_flows -= 1;
                self.finished.insert(id, stats);
                self.completions.push(Reverse((stats.completed_ns, id)));
            }
            return;
        }
        let link_idx = flow.path[ev.hop];
        let start = ev.time.max(self.link_free[link_idx]);
        // Full packets (the common case) use the cached per-link time.
        let ser = if ev.bytes == self.full_pkt_bytes[link_idx] {
            self.full_pkt_ser[link_idx]
        } else {
            (self.topo.ser_ns(link_idx, ev.bytes).round() as TimeNs).max(1)
        };
        // Cut-through: the link is busy for the serialization time; the
        // head reaches the next router after the hop pipeline latency and
        // the tail follows `ser` later.  The next-hop event is the tail
        // arrival so downstream serialization can't start early.
        self.link_free[link_idx] = start + ser;
        self.link_busy[link_idx] += ser;
        if let Some(buf) = &mut self.link_trace {
            buf.push(LinkTraceEvent {
                link: link_idx,
                flow: ev.flow,
                start_ns: start,
                dur_ns: ser,
                stall_ns: start - ev.time,
            });
        }
        let arrival = start + self.hop_ns + ser;
        // Book dynamic link energy at the source node of the link.
        let link = &self.topo.links[link_idx];
        let pj = ev.bytes as f64 * link.e_per_byte_pj;
        self.energy.push(link.src, start, pj);
        self.work += ev.bytes;
        let seq = self.seq();
        self.events.push(Reverse(PacketEvent {
            time: arrival,
            seq,
            flow: ev.flow,
            bytes: ev.bytes,
            hop: ev.hop + 1,
        }));
    }
}

impl NetworkSim for PacketEngine {
    fn inject(&mut self, spec: FlowSpec, now: TimeNs) -> FlowId {
        let id = self.next_flow_id;
        self.next_flow_id += 1;
        assert!(spec.src < self.topo.num_nodes && spec.dst < self.topo.num_nodes);
        let path = self
            .topo
            .path(spec.src, spec.dst)
            .expect("inject: unreachable destination (check Topology::reachable first)");
        if path.is_empty() {
            // Same-chiplet transfer: completes immediately (local SRAM).
            let stats = FlowStats { spec, injected_ns: now, completed_ns: now, hops: 0 };
            self.flows.push(None);
            self.finished.insert(id, stats);
            self.completions.push(Reverse((now, id)));
            return id;
        }
        let pkt_bytes = PACKET_FLITS * self.topo.links[path[0]].width_bytes;
        let bytes = spec.bytes.max(1);
        let full = bytes / pkt_bytes;
        let tail = bytes % pkt_bytes;
        let npackets = full + (tail > 0) as u64;
        debug_assert_eq!(self.flows.len(), id as usize);
        self.flows.push(Some(FlowState {
            spec,
            injected_ns: now,
            path,
            packets_left: npackets,
            last_arrival: now,
        }));
        self.active_flows += 1;
        // All packets enter the source NI queue at `now`; the first link's
        // FIFO serializes them (source injection bandwidth = link rate).
        for k in 0..npackets {
            let b = if k == full { tail } else { pkt_bytes };
            let seq = self.seq();
            self.events.push(Reverse(PacketEvent { time: now, seq, flow: id, bytes: b, hop: 0 }));
        }
        id
    }

    fn advance_until(&mut self, t: TimeNs) -> Option<FlowCompletion> {
        let _prof = crate::prof::scope(crate::prof::Subsystem::PacketEngine);
        loop {
            // Report any discovered completion that is due first.
            if let Some(&Reverse((ct, _))) = self.completions.peek() {
                let next_ev = self.events.peek().map(|Reverse(e)| e.time);
                if ct <= t && next_ev.map(|et| ct <= et).unwrap_or(true) {
                    let Reverse((time, id)) = self.completions.pop().unwrap();
                    return Some(FlowCompletion { id, time });
                }
            }
            match self.events.peek() {
                Some(Reverse(ev)) if ev.time <= t => {
                    let Reverse(ev) = self.events.pop().unwrap();
                    self.step_event(ev);
                }
                _ => {
                    // No more network activity before `t`; report leftover
                    // completions due by `t` if any.
                    if let Some(&Reverse((ct, _))) = self.completions.peek() {
                        if ct <= t {
                            let Reverse((time, id)) = self.completions.pop().unwrap();
                            return Some(FlowCompletion { id, time });
                        }
                    }
                    return None;
                }
            }
        }
    }

    fn has_active(&self) -> bool {
        self.active_flows > 0 || !self.completions.is_empty()
    }

    fn stats(&self, id: FlowId) -> Option<FlowStats> {
        self.finished.get(&id).copied()
    }

    fn comm_energy_pj(&self) -> f64 {
        self.energy.total_pj()
    }

    fn drain_energy_events(&mut self) -> Vec<(usize, TimeNs, f64)> {
        self.energy.drain()
    }

    fn set_energy_bin_ns(&mut self, bin_ns: TimeNs) {
        self.energy.set_bin_ns(bin_ns);
    }

    fn work_done(&self) -> u64 {
        self.work
    }

    fn link_busy_ns(&self) -> Vec<TimeNs> {
        self.link_busy.clone()
    }

    fn set_link_trace(&mut self, enabled: bool) {
        self.link_trace = if enabled { Some(Vec::new()) } else { None };
    }

    fn drain_link_trace(&mut self) -> Vec<LinkTraceEvent> {
        match &mut self.link_trace {
            Some(buf) => std::mem::take(buf),
            None => Vec::new(),
        }
    }

    fn apply_fault(&mut self, topo: &Topology, link_down: &[bool]) -> Vec<(FlowId, FlowSpec)> {
        debug_assert_eq!(topo.links.len(), self.topo.links.len(), "same link universe");
        // Adopt the rerouted tables; link indices are unchanged so all
        // per-link state (free times, busy counters) stays valid.
        self.topo.route = topo.route.clone();
        self.topo.hop_table = topo.hop_table.clone();
        // A flow is affected when its frozen path crosses a dead link:
        // packets already past it keep their booked energy/work (those
        // bytes did move), but the flow as a whole is lost and must be
        // retransmitted from the source — or abandoned by the caller.
        let mut dropped = Vec::new();
        for (id, slot) in self.flows.iter_mut().enumerate() {
            let affected =
                slot.as_ref().is_some_and(|f| f.path.iter().any(|&l| link_down[l]));
            if affected {
                let f = slot.take().expect("affected flow exists");
                self.active_flows -= 1;
                dropped.push((id as FlowId, f.spec));
            }
        }
        if !dropped.is_empty() {
            // Purge the dead flows' queued packet events.  Completed
            // flows also hold `None` slots but never have queued events,
            // so filtering on the slot is exact.
            let events = std::mem::take(&mut self.events);
            self.events = events
                .into_iter()
                .filter(|Reverse(e)| self.flows[e.flow as usize].is_some())
                .collect();
        }
        dropped
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::LinkParams;
    use crate::noc::topology::mesh;

    fn engine(rows: usize, cols: usize) -> PacketEngine {
        PacketEngine::new(mesh(rows, cols, &LinkParams::default()))
    }

    fn run_flow(e: &mut PacketEngine, spec: FlowSpec, at: TimeNs) -> FlowStats {
        let id = e.inject(spec, at);
        let done = e.advance_until(TimeNs::MAX).expect("flow completes");
        assert_eq!(done.id, id);
        e.stats(id).unwrap()
    }

    #[test]
    fn single_flow_latency_matches_hand_calc() {
        let mut e = engine(1, 2);
        // 512 B = exactly one 16-flit packet over a 32 B/cy 1 GHz link.
        // latency = hop(4cy) + ser(16cy) = 20 ns.
        let s = run_flow(&mut e, FlowSpec { src: 0, dst: 1, bytes: 512 }, 0);
        assert_eq!(s.latency_ns(), 20);
    }

    #[test]
    fn multi_packet_flow_pipelines() {
        let mut e = engine(1, 2);
        // 2048 B = 4 packets; serialization dominates: last tail leaves the
        // link at 4*16=64cy; its head left at 48, arrives 48+4, tail 48+4+16
        // = 68 ns.
        let s = run_flow(&mut e, FlowSpec { src: 0, dst: 1, bytes: 2048 }, 0);
        assert_eq!(s.latency_ns(), 68);
    }

    #[test]
    fn multi_hop_adds_pipeline_latency() {
        let mut e = engine(1, 4);
        // One packet, 3 hops: each hop adds hop(4) and ser(16) in sequence
        // because the tail must arrive before the next link starts:
        // 3 * 20 = 60 ns.
        let s = run_flow(&mut e, FlowSpec { src: 0, dst: 3, bytes: 512 }, 0);
        assert_eq!(s.latency_ns(), 60);
        assert_eq!(s.hops, 3);
    }

    #[test]
    fn contention_inflates_latency() {
        // Two flows share the middle link of a 1x3 line.
        let mut e = engine(1, 3);
        let a = e.inject(FlowSpec { src: 0, dst: 2, bytes: 4096 }, 0);
        let b = e.inject(FlowSpec { src: 1, dst: 2, bytes: 4096 }, 0);
        let mut done = Vec::new();
        while let Some(c) = e.advance_until(TimeNs::MAX) {
            done.push(c);
        }
        assert_eq!(done.len(), 2);
        let sa = e.stats(a).unwrap();
        let sb = e.stats(b).unwrap();
        // Flow b's packets hold link 1->2 from t=0, so flow a (whose
        // packets arrive at router 1 only after crossing 0->1) must queue
        // behind them: a is strictly slower than its solo time, while b
        // is no slower than solo.
        let mut solo = engine(1, 3);
        let sa_solo = run_flow(&mut solo, FlowSpec { src: 0, dst: 2, bytes: 4096 }, 0);
        let mut solo_b = engine(1, 3);
        let sb_solo = run_flow(&mut solo_b, FlowSpec { src: 1, dst: 2, bytes: 4096 }, 0);
        assert!(
            sa.latency_ns() > sa_solo.latency_ns(),
            "{} !> {}",
            sa.latency_ns(),
            sa_solo.latency_ns()
        );
        assert!(sb.latency_ns() >= sb_solo.latency_ns());
    }

    #[test]
    fn same_node_flow_completes_instantly() {
        let mut e = engine(2, 2);
        let s = run_flow(&mut e, FlowSpec { src: 1, dst: 1, bytes: 100_000 }, 42);
        assert_eq!(s.latency_ns(), 0);
        assert_eq!(s.hops, 0);
    }

    #[test]
    fn advance_until_respects_time_bound() {
        let mut e = engine(1, 2);
        e.inject(FlowSpec { src: 0, dst: 1, bytes: 512 }, 0);
        // Completion is at 20 ns; asking for 10 ns returns nothing.
        assert!(e.advance_until(10).is_none());
        assert!(e.has_active());
        let c = e.advance_until(20).unwrap();
        assert_eq!(c.time, 20);
        assert!(!e.has_active());
    }

    #[test]
    fn completions_reported_in_time_order() {
        let mut e = engine(1, 4);
        let near = e.inject(FlowSpec { src: 2, dst: 3, bytes: 512 }, 0);
        let far = e.inject(FlowSpec { src: 0, dst: 3, bytes: 65536 }, 0);
        let c1 = e.advance_until(TimeNs::MAX).unwrap();
        let c2 = e.advance_until(TimeNs::MAX).unwrap();
        assert_eq!(c1.id, near);
        assert_eq!(c2.id, far);
        assert!(c1.time <= c2.time);
    }

    #[test]
    fn energy_scales_with_bytes_and_hops() {
        let mut e = engine(1, 4);
        run_flow(&mut e, FlowSpec { src: 0, dst: 3, bytes: 1000 }, 0);
        // 1000 bytes * 3 hops * 1.2 pJ/B.
        let expect = 1000.0 * 3.0 * 1.2;
        assert!((e.comm_energy_pj() - expect).abs() < 1e-6);
        let events = e.drain_energy_events();
        assert!(!events.is_empty());
        let sum: f64 = events.iter().map(|&(_, _, pj)| pj).sum();
        assert!((sum - expect).abs() < 1e-6);
    }

    #[test]
    fn energy_coalescing_preserves_totals() {
        let run = |bin: TimeNs| {
            let mut e = engine(1, 4);
            e.set_energy_bin_ns(bin);
            run_flow(&mut e, FlowSpec { src: 0, dst: 3, bytes: 10_000 }, 0);
            let ev = e.drain_energy_events();
            (ev.len(), ev.iter().map(|&(_, _, pj)| pj).sum::<f64>(), e.comm_energy_pj())
        };
        let (n_fine, sum_fine, total_fine) = run(1);
        let (n_bin, sum_bin, total_bin) = run(1_000);
        assert!(n_bin <= n_fine, "{n_bin} !<= {n_fine}");
        assert!((sum_fine - sum_bin).abs() < 1e-6);
        assert_eq!(total_fine.to_bits(), total_bin.to_bits());
    }

    #[test]
    fn link_trace_matches_busy_time() {
        let mut e = engine(1, 3);
        e.set_link_trace(true);
        let id = e.inject(FlowSpec { src: 0, dst: 2, bytes: 4096 }, 0);
        while e.advance_until(TimeNs::MAX).is_some() {}
        let trace = e.drain_link_trace();
        assert!(!trace.is_empty());
        assert!(trace.iter().all(|t| t.flow == id && t.dur_ns > 0));
        // Per-link trace durations reproduce the busy-time accounting.
        let busy = e.link_busy_ns();
        for (link, &b) in busy.iter().enumerate() {
            let traced: TimeNs =
                trace.iter().filter(|t| t.link == link).map(|t| t.dur_ns).sum();
            assert_eq!(traced, b, "link {link}");
        }
        // Drain is destructive; untraced runs yield nothing.
        assert!(e.drain_link_trace().is_empty());
        e.set_link_trace(false);
        run_flow(&mut e, FlowSpec { src: 0, dst: 1, bytes: 512 }, 1_000_000);
        assert!(e.drain_link_trace().is_empty());
    }

    #[test]
    fn apply_fault_drops_crossing_flows_and_adopts_reroutes() {
        // 2x2 mesh: X-Y routes 0->3 via 1.  Kill both halves of 0<->1:
        // the in-flight flow is dropped; a re-injection routes via 2.
        let mut e = engine(2, 2);
        let id = e.inject(FlowSpec { src: 0, dst: 3, bytes: 65536 }, 0);
        let bystander = e.inject(FlowSpec { src: 3, dst: 2, bytes: 512 }, 0);
        let mut masked = e.topology().clone();
        let down: Vec<bool> = masked
            .links
            .iter()
            .map(|l| (l.src == 0 && l.dst == 1) || (l.src == 1 && l.dst == 0))
            .collect();
        masked.apply_link_mask(&down);
        assert_eq!(masked.hops(0, 3), Some(2), "0->3 survives via node 2");
        let dropped = e.apply_fault(&masked, &down);
        assert_eq!(dropped, vec![(id, FlowSpec { src: 0, dst: 3, bytes: 65536 })]);
        // The bystander flow (3->2->... never touches 0<->1) finishes.
        let c = e.advance_until(TimeNs::MAX).expect("bystander completes");
        assert_eq!(c.id, bystander);
        assert!(e.advance_until(TimeNs::MAX).is_none());
        // Retransmission takes the detour and completes.
        let retry = e.inject(FlowSpec { src: 0, dst: 3, bytes: 65536 }, c.time);
        let done = e.advance_until(TimeNs::MAX).expect("retry completes");
        assert_eq!(done.id, retry);
        assert_eq!(e.stats(retry).unwrap().hops, 2);
    }

    #[test]
    fn apply_fault_with_no_dead_links_is_invisible() {
        let mut run = |fault: bool| {
            let mut e = engine(2, 2);
            e.inject(FlowSpec { src: 0, dst: 3, bytes: 4096 }, 0);
            if fault {
                let topo = e.topology().clone();
                let down = vec![false; topo.links.len()];
                assert!(e.apply_fault(&topo, &down).is_empty());
            }
            let c = e.advance_until(TimeNs::MAX).unwrap();
            (c.id, c.time, e.work_done())
        };
        assert_eq!(run(false), run(true));
    }

    #[test]
    fn deterministic_across_runs() {
        let mk = || {
            let mut e = engine(4, 4);
            for i in 0..20 {
                e.inject(
                    FlowSpec { src: i % 16, dst: (i * 7 + 3) % 16, bytes: 1000 + i as u64 * 333 },
                    (i as TimeNs) * 10,
                );
            }
            let mut out = Vec::new();
            while let Some(c) = e.advance_until(TimeNs::MAX) {
                out.push((c.id, c.time));
            }
            out
        };
        assert_eq!(mk(), mk());
    }
}
