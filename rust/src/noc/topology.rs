//! NoI topologies and routing tables.
//!
//! Supports the paper's configurations: 2-D mesh with X-Y routing
//! [23, 29], the Floret space-filling-curve topology [18], the AMD
//! CCD↔IOD star used for hardware validation (§V-F, with asymmetric
//! per-direction GMI3 link widths), and arbitrary custom link lists.
//!
//! Heterogeneous links are first-class: every directed link carries its
//! own width and clock divider, as HeteroGarnet does for mixed 2.5D/3D
//! interposers.

use crate::config::{HardwareConfig, LinkParams, TopologyKind};

/// One directed physical link.
#[derive(Debug, Clone)]
pub struct Link {
    pub src: usize,
    pub dst: usize,
    /// Bytes transferred per link cycle.
    pub width_bytes: u64,
    /// Clock divider relative to the base NoI clock (2 = half rate).
    pub clock_div: u64,
    /// Dynamic energy per byte, pJ.
    pub e_per_byte_pj: f64,
}

/// A routed topology: nodes, directed links, and next-hop tables.
#[derive(Debug, Clone)]
pub struct Topology {
    pub num_nodes: usize,
    pub links: Vec<Link>,
    /// Outgoing link indices per node, in ascending link-index order.
    pub out_links: Vec<Vec<usize>>,
    /// Incoming link indices per node, in ascending link-index order.
    /// Precomputed once so per-router input lists are O(degree) lookups;
    /// the flit engine's switch allocator used to rebuild this by
    /// scanning every link of the topology on every cycle.
    pub in_links: Vec<Vec<usize>>,
    /// `route[src][dst]` = link index of the next hop (usize::MAX on diag).
    pub route: Vec<Vec<usize>>,
    /// `hop_table[src][dst]` = hop count of the routed path (0 on diag).
    /// Precomputed so the mapper's distance queries are O(1) — `hops()`
    /// on the hot mapping path used to walk (and allocate) the full path.
    pub hop_table: Vec<Vec<u16>>,
    /// Base cycle time, ns.
    pub cycle_ns: f64,
    /// Router pipeline latency per hop, cycles.
    pub hop_latency_cycles: u64,
}

impl Topology {
    /// Build the topology + routing for a hardware configuration.
    pub fn build(hw: &HardwareConfig) -> Topology {
        match &hw.topology {
            TopologyKind::Mesh => mesh(hw.rows, hw.cols, &hw.link),
            TopologyKind::Floret { petals } => floret(hw.rows, hw.cols, *petals, &hw.link),
            TopologyKind::CcdStar => ccd_star(hw.num_chiplets() - 2, &hw.link),
            TopologyKind::Custom { links } => custom(hw.num_chiplets(), links, &hw.link),
        }
    }

    /// Path (sequence of link indices) from src to dst, or `None` when
    /// `dst` is unreachable (disconnected `custom()` graph, or links
    /// masked out by [`apply_link_mask`](Self::apply_link_mask)).  An
    /// empty path (`src == dst`) is `Some(vec![])`.
    pub fn path(&self, src: usize, dst: usize) -> Option<Vec<usize>> {
        let mut path = Vec::new();
        let mut cur = src;
        while cur != dst {
            let l = self.route[cur][dst];
            if l == usize::MAX || path.len() >= self.num_nodes {
                return None; // unreachable (or a routing loop: same answer)
            }
            path.push(l);
            cur = self.links[l].dst;
        }
        Some(path)
    }

    /// Hop count between two nodes (O(1) table lookup), or `None` when
    /// `dst` is unreachable from `src`.
    pub fn hops(&self, src: usize, dst: usize) -> Option<usize> {
        match self.hop_table[src][dst] {
            u16::MAX => None,
            h => Some(h as usize),
        }
    }

    /// True when `dst` is reachable from `src` under the current routing
    /// tables.
    pub fn reachable(&self, src: usize, dst: usize) -> bool {
        self.hop_table[src][dst] != u16::MAX
    }

    /// Recompute the hop table from the current routing tables (must be
    /// called after any manual `route` override, e.g. mesh X-Y).
    /// Unreachable pairs get the `u16::MAX` sentinel.
    fn rebuild_hop_table(&mut self) {
        let n = self.num_nodes;
        let mut table = vec![vec![0u16; n]; n];
        for s in 0..n {
            for d in 0..n {
                if s == d {
                    continue;
                }
                let mut cur = s;
                let mut h = 0u16;
                loop {
                    if cur == d {
                        break;
                    }
                    let l = self.route[cur][d];
                    if l == usize::MAX || (h as usize) >= n {
                        h = u16::MAX;
                        break;
                    }
                    cur = self.links[l].dst;
                    h += 1;
                }
                table[s][d] = h;
            }
        }
        self.hop_table = table;
    }

    /// Reroute around failed links: recompute the next-hop and hop
    /// tables by BFS over the alive links only.  `link_down[i]` marks
    /// link `i` as failed.  Link indices, the link list, and the
    /// adjacency tables are left untouched, so engine-side per-link
    /// state (occupancy, buffers, credits) stays valid across a mask
    /// change; pairs partitioned by the mask become unreachable
    /// ([`path`](Self::path)/[`hops`](Self::hops) return `None`).
    ///
    /// Note: a masked mesh falls back to minimal BFS routes (the X-Y
    /// dimension-order override cannot route around a dead link).  To
    /// restore the pristine routing after repair, rebuild from an
    /// unmasked clone instead of applying an all-false mask.
    pub fn apply_link_mask(&mut self, link_down: &[bool]) {
        assert_eq!(link_down.len(), self.links.len(), "link mask length");
        self.route = bfs_routes(self, Some(link_down));
        self.rebuild_hop_table();
    }

    /// Serialization time of `bytes` over link `l`, in ns.
    pub fn ser_ns(&self, l: usize, bytes: u64) -> f64 {
        let link = &self.links[l];
        let cycles = bytes.div_ceil(link.width_bytes) * link.clock_div;
        cycles as f64 * self.cycle_ns
    }

    /// Per-hop router latency in ns.
    pub fn hop_ns(&self) -> f64 {
        self.hop_latency_cycles as f64 * self.cycle_ns
    }

    fn with_links(num_nodes: usize, links: Vec<Link>, p: &LinkParams) -> Topology {
        let mut out_links = vec![Vec::new(); num_nodes];
        let mut in_links = vec![Vec::new(); num_nodes];
        for (i, l) in links.iter().enumerate() {
            out_links[l.src].push(i);
            in_links[l.dst].push(i);
        }
        let mut t = Topology {
            num_nodes,
            links,
            out_links,
            in_links,
            route: Vec::new(),
            hop_table: Vec::new(),
            cycle_ns: 1.0 / p.clock_ghz,
            hop_latency_cycles: p.hop_latency_cycles,
        };
        t.route = bfs_routes(&t, None);
        t.rebuild_hop_table();
        t
    }
}

/// All-pairs next-hop via per-destination BFS (deterministic tie-break by
/// link index order => stable, minimal routes).  `link_down` masks out
/// failed links; unreachable pairs keep the `usize::MAX` sentinel.
fn bfs_routes(t: &Topology, link_down: Option<&[bool]>) -> Vec<Vec<usize>> {
    let n = t.num_nodes;
    let mut route = vec![vec![usize::MAX; n]; n];
    // BFS from each destination over reversed edges (precomputed
    // `in_links` adjacency).
    let in_links = &t.in_links;
    for dst in 0..n {
        let mut dist = vec![usize::MAX; n];
        dist[dst] = 0;
        let mut queue = std::collections::VecDeque::new();
        queue.push_back(dst);
        while let Some(v) = queue.pop_front() {
            for &li in &in_links[v] {
                if link_down.is_some_and(|m| m[li]) {
                    continue;
                }
                let u = t.links[li].src;
                if dist[u] == usize::MAX {
                    dist[u] = dist[v] + 1;
                    route[u][dst] = li;
                    queue.push_back(u);
                }
            }
        }
    }
    route
}

// -------------------------------------------------------------------- mesh

fn mesh_links(rows: usize, cols: usize, p: &LinkParams) -> Vec<Link> {
    let mut links = Vec::new();
    let id = |r: usize, c: usize| r * cols + c;
    let mut push = |a: usize, b: usize| {
        links.push(Link {
            src: a,
            dst: b,
            width_bytes: p.width_bytes,
            clock_div: 1,
            e_per_byte_pj: p.e_per_byte_pj,
        });
    };
    for r in 0..rows {
        for c in 0..cols {
            if c + 1 < cols {
                push(id(r, c), id(r, c + 1));
                push(id(r, c + 1), id(r, c));
            }
            if r + 1 < rows {
                push(id(r, c), id(r + 1, c));
                push(id(r + 1, c), id(r, c));
            }
        }
    }
    links
}

/// 2-D mesh with dimension-ordered X-Y routing (deadlock-free).
pub fn mesh(rows: usize, cols: usize, p: &LinkParams) -> Topology {
    let links = mesh_links(rows, cols, p);
    let mut t = Topology::with_links(rows * cols, links, p);
    // Replace BFS routes with X-Y dimension order: move along X (columns)
    // first, then Y (rows) — the paper's NoI uses X-Y routing (§V-A).
    let id = |r: usize, c: usize| r * cols + c;
    let mut link_of = std::collections::HashMap::new();
    for (i, l) in t.links.iter().enumerate() {
        link_of.insert((l.src, l.dst), i);
    }
    for sr in 0..rows {
        for sc in 0..cols {
            let s = id(sr, sc);
            for dr in 0..rows {
                for dc in 0..cols {
                    let d = id(dr, dc);
                    if s == d {
                        continue;
                    }
                    let next = if sc != dc {
                        // X first.
                        if dc > sc { id(sr, sc + 1) } else { id(sr, sc - 1) }
                    } else if dr > sr {
                        id(sr + 1, sc)
                    } else {
                        id(sr - 1, sc)
                    };
                    t.route[s][d] = link_of[&(s, next)];
                }
            }
        }
    }
    t.rebuild_hop_table();
    t
}

// ------------------------------------------------------------------ floret

/// Floret NoI [18]: data-flow-aware petals. The non-hub chiplets are
/// partitioned into `petals` chains by angular order around a central hub;
/// each petal is a loop hub -> n1 -> ... -> nk -> hub, aligning the
/// topology with feed-forward layer traffic (consecutive layers sit on
/// consecutive petal nodes).  Routing: shortest path (BFS), which follows
/// petals and crosses the hub between petals.
pub fn floret(rows: usize, cols: usize, petals: usize, p: &LinkParams) -> Topology {
    let n = rows * cols;
    assert!(petals >= 1 && n > 1);
    let hub = (rows / 2) * cols + cols / 2;
    let pos = |i: usize| ((i / cols) as f64, (i % cols) as f64);
    let (hr, hc) = pos(hub);
    // Sort non-hub nodes by angle around the hub, then by radius.
    let mut others: Vec<usize> = (0..n).filter(|&i| i != hub).collect();
    others.sort_by(|&a, &b| {
        let (ar, ac) = pos(a);
        let (br, bc) = pos(b);
        let ta = (ar - hr).atan2(ac - hc);
        let tb = (br - hr).atan2(bc - hc);
        ta.partial_cmp(&tb)
            .unwrap()
            .then_with(|| {
                let da = (ar - hr).hypot(ac - hc);
                let db = (br - hr).hypot(bc - hc);
                da.partial_cmp(&db).unwrap()
            })
            .then(a.cmp(&b))
    });
    let mut links = Vec::new();
    let mut push = |a: usize, b: usize| {
        links.push(Link {
            src: a,
            dst: b,
            width_bytes: p.width_bytes,
            clock_div: 1,
            e_per_byte_pj: p.e_per_byte_pj,
        });
        links.push(Link {
            src: b,
            dst: a,
            width_bytes: p.width_bytes,
            clock_div: 1,
            e_per_byte_pj: p.e_per_byte_pj,
        });
    };
    let per = others.len().div_ceil(petals);
    for chunk in others.chunks(per) {
        // hub -> c0 -> c1 ... -> ck -> hub (petal loop).
        let mut prev = hub;
        for &node in chunk {
            push(prev, node);
            prev = node;
        }
        if prev != hub {
            push(prev, hub);
        }
    }
    Topology::with_links(n, links, p)
}

// ---------------------------------------------------------------- ccd star

/// AMD Threadripper PRO-like star (§V-F): `num_ccds` CCDs each linked to
/// one IOD by GMI3 (asymmetric: 32 B/cy read i.e. IOD->CCD, 16 B/cy write
/// i.e. CCD->IOD, both at the base 1.733 GHz clock), and the IOD linked to
/// a DDR endpoint node whose width models aggregate DDR5 bandwidth.
pub fn ccd_star(num_ccds: usize, p: &LinkParams) -> Topology {
    let iod = num_ccds;
    let ddr = num_ccds + 1;
    let n = num_ccds + 2;
    let mut links = Vec::new();
    for ccd in 0..num_ccds {
        // Read direction (IOD -> CCD): 32 B/cycle.
        links.push(Link {
            src: iod,
            dst: ccd,
            width_bytes: 32,
            clock_div: 1,
            e_per_byte_pj: p.e_per_byte_pj,
        });
        // Write direction (CCD -> IOD): 16 B/cycle.
        links.push(Link {
            src: ccd,
            dst: iod,
            width_bytes: 16,
            clock_div: 1,
            e_per_byte_pj: p.e_per_byte_pj,
        });
    }
    // IOD <-> DDR: aggregate DDR5 ~330 GB/s at 1.733 GHz ≈ 190 B/cycle.
    for (a, b, w) in [(iod, ddr, 190u64), (ddr, iod, 190u64)] {
        links.push(Link { src: a, dst: b, width_bytes: w, clock_div: 1, e_per_byte_pj: p.e_per_byte_pj });
    }
    Topology::with_links(n, links, p)
}

// ------------------------------------------------------------------ custom

/// Arbitrary undirected link list.
pub fn custom(num_nodes: usize, undirected: &[(usize, usize)], p: &LinkParams) -> Topology {
    let mut links = Vec::new();
    for &(a, b) in undirected {
        assert!(a < num_nodes && b < num_nodes, "link ({a},{b}) out of range");
        for (s, d) in [(a, b), (b, a)] {
            links.push(Link {
                src: s,
                dst: d,
                width_bytes: p.width_bytes,
                clock_div: 1,
                e_per_byte_pj: p.e_per_byte_pj,
            });
        }
    }
    Topology::with_links(num_nodes, links, p)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p() -> LinkParams {
        LinkParams::default()
    }

    #[test]
    fn mesh_link_count() {
        let t = mesh(4, 4, &p());
        // 2 * (rows*(cols-1) + cols*(rows-1)) directed links.
        assert_eq!(t.links.len(), 2 * (4 * 3 + 4 * 3));
    }

    #[test]
    fn mesh_xy_routing_goes_x_first() {
        let t = mesh(4, 4, &p());
        // From (0,0)=0 to (2,3)=11: first hops along the row: 0->1->2->3,
        // then down the column: 3->7->11.
        let path = t.path(0, 11).unwrap();
        let nodes: Vec<usize> = path.iter().map(|&l| t.links[l].dst).collect();
        assert_eq!(nodes, vec![1, 2, 3, 7, 11]);
    }

    #[test]
    fn mesh_hops_equal_manhattan() {
        let t = mesh(10, 10, &p());
        for (s, d) in [(0usize, 99usize), (5, 50), (23, 67), (99, 0)] {
            let (sr, sc) = (s / 10, s % 10);
            let (dr, dc) = (d / 10, d % 10);
            let manhattan = sr.abs_diff(dr) + sc.abs_diff(dc);
            assert_eq!(t.hops(s, d), Some(manhattan), "{s}->{d}");
        }
    }

    #[test]
    fn floret_is_fully_connected() {
        let t = floret(10, 10, 10, &p());
        for s in 0..t.num_nodes {
            for d in 0..t.num_nodes {
                if s != d {
                    assert!(!t.path(s, d).unwrap().is_empty());
                }
            }
        }
    }

    #[test]
    fn floret_neighbours_on_petal_are_one_hop() {
        let t = floret(6, 6, 6, &p());
        // Every link endpoint pair must be one hop apart.
        for l in &t.links {
            assert_eq!(t.hops(l.src, l.dst), Some(1));
        }
    }

    #[test]
    fn ccd_star_asymmetric_widths() {
        let t = ccd_star(8, &p());
        let read = t.links.iter().find(|l| l.src == 8 && l.dst == 0).unwrap();
        let write = t.links.iter().find(|l| l.src == 0 && l.dst == 8).unwrap();
        assert_eq!(read.width_bytes, 32);
        assert_eq!(write.width_bytes, 16);
        // CCD-to-CCD goes through the IOD: 2 hops.
        assert_eq!(t.hops(0, 5), Some(2));
        // CCD to DDR: 2 hops via IOD.
        assert_eq!(t.hops(3, 9), Some(2));
    }

    #[test]
    fn ser_ns_respects_width_and_clock_div() {
        let mut t = mesh(2, 2, &p());
        assert_eq!(t.ser_ns(0, 32), 1.0); // 32 B over 32 B/cy @1 GHz = 1 cy
        assert_eq!(t.ser_ns(0, 33), 2.0); // partial flit rounds up
        t.links[0].clock_div = 2;
        assert_eq!(t.ser_ns(0, 32), 2.0);
    }

    #[test]
    fn custom_topology_routes() {
        // A line 0-1-2-3.
        let t = custom(4, &[(0, 1), (1, 2), (2, 3)], &p());
        assert_eq!(t.hops(0, 3), Some(3));
        assert_eq!(t.hops(3, 0), Some(3));
    }

    #[test]
    #[should_panic]
    fn custom_rejects_out_of_range() {
        custom(2, &[(0, 5)], &p());
    }

    #[test]
    fn disconnected_custom_graph_reports_unreachable() {
        // Two islands: 0-1 and 2-3.
        let t = custom(4, &[(0, 1), (2, 3)], &p());
        assert_eq!(t.hops(0, 1), Some(1));
        assert_eq!(t.hops(0, 2), None);
        assert_eq!(t.path(1, 3), None);
        assert!(!t.reachable(3, 0));
        assert_eq!(t.path(2, 2), Some(vec![]));
    }

    #[test]
    fn link_mask_reroutes_or_partitions() {
        // A ring 0-1-2-3-0: killing both directions of 0<->1 reroutes
        // 0->1 the long way; killing 1<->2 as well strands node 1.
        let t0 = custom(4, &[(0, 1), (1, 2), (2, 3), (3, 0)], &p());
        assert_eq!(t0.hops(0, 1), Some(1));
        let dead = |t: &Topology, pairs: &[(usize, usize)]| -> Vec<bool> {
            t.links
                .iter()
                .map(|l| {
                    pairs.iter().any(|&(a, b)| {
                        (l.src == a && l.dst == b) || (l.src == b && l.dst == a)
                    })
                })
                .collect()
        };
        let mut t = t0.clone();
        t.apply_link_mask(&dead(&t0, &[(0, 1)]));
        assert_eq!(t.hops(0, 1), Some(3), "rerouted via 3 and 2");
        assert_eq!(t.hops(0, 2), Some(2));
        let path = t.path(0, 1).unwrap();
        assert!(path.iter().all(|&l| !(t.links[l].src == 0 && t.links[l].dst == 1)));
        let mut t = t0.clone();
        t.apply_link_mask(&dead(&t0, &[(0, 1), (1, 2)]));
        assert_eq!(t.hops(0, 1), None, "node 1 is partitioned");
        assert_eq!(t.path(2, 1), None);
        assert_eq!(t.hops(0, 2), Some(2), "survivors still route");
        // Link list and adjacency are untouched by masking.
        assert_eq!(t.links.len(), t0.links.len());
        assert_eq!(t.out_links, t0.out_links);
    }

    #[test]
    fn adjacency_tables_match_link_list() {
        for t in [mesh(4, 5, &p()), floret(4, 4, 4, &p()), ccd_star(6, &p())] {
            for n in 0..t.num_nodes {
                // Sorted ascending, and consistent with the link list.
                assert!(t.in_links[n].windows(2).all(|w| w[0] < w[1]));
                assert!(t.out_links[n].windows(2).all(|w| w[0] < w[1]));
                for &l in &t.in_links[n] {
                    assert_eq!(t.links[l].dst, n);
                }
                for &l in &t.out_links[n] {
                    assert_eq!(t.links[l].src, n);
                }
            }
            let in_total: usize = t.in_links.iter().map(|v| v.len()).sum();
            assert_eq!(in_total, t.links.len());
        }
    }
}
