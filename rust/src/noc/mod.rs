//! Network-on-interposer simulator (our from-scratch HeteroGarnet analog).
//!
//! The inter-chiplet network is the *shared* resource of the co-simulation:
//! a single network simulation accounts for all active chiplet-to-chiplet
//! flows of all DNN models simultaneously, so contention between layer
//! traffic emerges from link arbitration rather than being post-hoc
//! estimated (paper §III-D/E).
//!
//! Two fidelity levels share the same [`topology::Topology`]:
//!
//! * [`engine::PacketEngine`] — event-driven virtual-cut-through model at
//!   packet (16-flit) granularity: per-link FIFO serialization, cut-through
//!   pipelining across hops, heterogeneous link widths/clocks.  Default —
//!   fastest, coarsest contention model.
//! * [`flit::FlitEngine`] — cycle-driven wormhole model with per-port
//!   input buffers, credit flow control and round-robin switch allocation
//!   (`--noc flit`).  Production-fast: an active-set scheduler touches
//!   only routers that can move and idle stretches are cycle-skipped, so
//!   its cost scales with traffic, not with `cycles × links` — pick it
//!   whenever per-flit arbitration accuracy matters, at any system size.
//!
//! Both implement [`NetworkSim`], the interface the Global Manager drives
//! in lockstep with the global event queue.

pub mod engine;
pub mod flit;
pub mod topology;

use crate::TimeNs;

/// Identifier of an injected flow (message).
pub type FlowId = u64;

/// A chiplet-to-chiplet activation transfer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FlowSpec {
    pub src: usize,
    pub dst: usize,
    pub bytes: u64,
}

/// A completed flow notification.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FlowCompletion {
    pub id: FlowId,
    pub time: TimeNs,
}

/// Per-flow statistics retained after completion.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FlowStats {
    pub spec: FlowSpec,
    pub injected_ns: TimeNs,
    pub completed_ns: TimeNs,
    pub hops: u32,
}

impl FlowStats {
    pub fn latency_ns(&self) -> TimeNs {
        self.completed_ns - self.injected_ns
    }
}

/// Interface between the Global Manager and a network engine.
///
/// Contract: `advance_until(t)` simulates network activity up to *and
/// including* time `t` and returns the **earliest** not-yet-reported flow
/// completion with `time <= t`, or `None` once none remain.  The manager
/// calls it repeatedly before processing any global event at `t`, so flow
/// completions interleave correctly with compute events on the coherent
/// global timeline.
///
/// `Send` so a whole run session can migrate across fleet worker-pool
/// threads between epochs; an engine is owned by one run at a time.
pub trait NetworkSim: Send {
    /// Inject a flow at time `now` (must be >= all previously passed times).
    fn inject(&mut self, spec: FlowSpec, now: TimeNs) -> FlowId;
    /// Advance to `t`; return the earliest unreported completion <= t.
    fn advance_until(&mut self, t: TimeNs) -> Option<FlowCompletion>;
    /// True while any injected flow is still in flight.
    fn has_active(&self) -> bool;
    /// Stats for a completed flow.
    fn stats(&self, id: FlowId) -> Option<FlowStats>;
    /// Total dynamic NoI energy so far, pJ, and per-node attribution.
    fn comm_energy_pj(&self) -> f64;
    /// Drain (node, time, energy_pj) events accumulated since last call —
    /// consumed by the power tracker at 1 µs bins.
    fn drain_energy_events(&mut self) -> Vec<(usize, TimeNs, f64)>;
    /// Hint the granularity at which drained energy events are consumed.
    /// Engines may coalesce per-hop energy into one event per (node, bin)
    /// — the Global Manager passes its power-tracker bin so the binned
    /// profile is unchanged while the event list shrinks by orders of
    /// magnitude.  Default: ignored (per-hop events).
    fn set_energy_bin_ns(&mut self, _bin_ns: TimeNs) {}
    /// Sum of flit-hops (or byte-hops) simulated — throughput metric.
    fn work_done(&self) -> u64;
    /// Cumulative busy time per link, ns (utilization = busy / span).
    /// Feeds the link-utilization statistics used for NoI bottleneck
    /// analysis (Fig. 7 root-causing) and DSE reports.
    fn link_busy_ns(&self) -> Vec<TimeNs> {
        Vec::new()
    }
    /// Enable/disable per-link occupancy tracing.  Off by default; the
    /// flight recorder ([`crate::trace`]) turns it on so engines log
    /// [`LinkTraceEvent`]s for every link occupancy.  Default: ignored
    /// (engines without link tracing simply produce no events).
    fn set_link_trace(&mut self, _enabled: bool) {}
    /// Drain link-occupancy events accumulated since the last call (in
    /// deterministic simulation order).  Default: none.
    fn drain_link_trace(&mut self) -> Vec<LinkTraceEvent> {
        Vec::new()
    }
    /// Adopt a fault-rerouted topology (same links/indices, different
    /// next-hop tables — see [`topology::Topology::apply_link_mask`];
    /// `link_down[i]` marks directed link `i` failed).  The engine must
    /// drop every in-flight flow whose progress touches a dead link and
    /// return those flows' `(id, spec)` in ascending id order, so the
    /// caller can decide per flow: re-inject from the source over the
    /// surviving paths (a retransmission), or abort the owning request
    /// when the destination is partitioned.  Unaffected flows continue;
    /// new injections use the new routes.  Default: no flows affected
    /// (engines without fault support keep their original routing).
    fn apply_fault(
        &mut self,
        _topo: &topology::Topology,
        _link_down: &[bool],
    ) -> Vec<(FlowId, FlowSpec)> {
        Vec::new()
    }
}

/// One link occupancy recorded by an engine with link tracing enabled:
/// flow `flow` held link `link` for `[start_ns, start_ns + dur_ns)`,
/// having waited `stall_ns` behind earlier traffic for the grant
/// (`0` when the engine cannot attribute stalls per occupancy).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LinkTraceEvent {
    pub link: usize,
    pub flow: FlowId,
    pub start_ns: TimeNs,
    pub dur_ns: TimeNs,
    pub stall_ns: TimeNs,
}

/// Coalescing accumulator for (node, time, energy_pj) dynamic-energy
/// events.
///
/// The flit engine books one event per flit-hop and the packet engine one
/// per packet-hop; the consumer ([`crate::power::PowerTracker`]) only
/// resolves them to `bin_ns` buckets anyway.  `EnergyLog` therefore folds
/// every event that lands in the same (node, bin) as the node's previous
/// event into that entry (timestamped at the bin start), instead of one
/// heap entry per hop.  With the default `bin_ns = 1` coalescing only
/// merges same-timestamp hops; engines inherit the real tracker bin via
/// [`NetworkSim::set_energy_bin_ns`].  Totals are preserved exactly: the
/// running `total_pj` adds per hop in booking order regardless of how
/// entries coalesce.
#[derive(Debug, Clone)]
pub struct EnergyLog {
    events: Vec<(usize, TimeNs, f64)>,
    /// Index of each node's most recent entry in `events` (usize::MAX
    /// when none since the last drain) — O(1) coalescing, no hashing.
    last: Vec<usize>,
    bin_ns: TimeNs,
    total_pj: f64,
}

impl EnergyLog {
    pub fn new(num_nodes: usize) -> EnergyLog {
        EnergyLog { events: Vec::new(), last: vec![usize::MAX; num_nodes], bin_ns: 1, total_pj: 0.0 }
    }

    /// Set the coalescing granularity (clamped to >= 1 ns).
    pub fn set_bin_ns(&mut self, bin_ns: TimeNs) {
        self.bin_ns = bin_ns.max(1);
    }

    /// Book `pj` of dynamic energy at `node` at time `t`.
    pub fn push(&mut self, node: usize, t: TimeNs, pj: f64) {
        self.total_pj += pj;
        let stamp = t - t % self.bin_ns;
        if let Some(e) = self.events.get_mut(self.last[node]) {
            if e.1 == stamp {
                e.2 += pj;
                return;
            }
        }
        self.last[node] = self.events.len();
        self.events.push((node, stamp, pj));
    }

    /// Take all pending events (each at most one per (node, bin) since
    /// the previous drain, per node-consecutive booking).
    pub fn drain(&mut self) -> Vec<(usize, TimeNs, f64)> {
        self.last.fill(usize::MAX);
        std::mem::take(&mut self.events)
    }

    /// Total energy booked so far (exact running sum, unaffected by
    /// coalescing).
    pub fn total_pj(&self) -> f64 {
        self.total_pj
    }

    pub fn pending_events(&self) -> usize {
        self.events.len()
    }
}

/// Per-tenant NoI traffic totals (multi-tenant flow attribution).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct TenantComm {
    /// Flows injected on the tenant's behalf.
    pub flows: u64,
    /// Payload bytes moved.
    pub bytes: u64,
    /// Bytes × route hops — the tenant's share of NoI work, comparable
    /// to [`NetworkSim::work_done`].
    pub byte_hops: u64,
}

/// Attribution of injected flows to tenants.
///
/// The Global Manager knows which tenant owns every flow it injects
/// (weight loads and activation transfers alike); this accumulator turns
/// that knowledge into per-tenant traffic totals so a multi-tenant mix
/// can report each tenant's share of the *shared* interposer — the
/// quantity that explains cross-tenant interference.  Engines stay
/// tenant-blind: contention arises from arbitration over the same links,
/// attribution happens at the injection boundary.
#[derive(Debug, Clone, Default)]
pub struct TenantTraffic {
    per: Vec<TenantComm>,
}

impl TenantTraffic {
    pub fn new() -> TenantTraffic {
        TenantTraffic::default()
    }

    /// Book one injected flow for `tenant` (`hops` along its route).
    pub fn add_flow(&mut self, tenant: usize, bytes: u64, hops: usize) {
        if tenant >= self.per.len() {
            self.per.resize(tenant + 1, TenantComm::default());
        }
        let t = &mut self.per[tenant];
        t.flows += 1;
        t.bytes += bytes;
        t.byte_hops += bytes * hops as u64;
    }

    /// Totals per tenant index (dense; tenants that injected nothing are
    /// zero entries).
    pub fn per_tenant(&self) -> &[TenantComm] {
        &self.per
    }

    pub fn into_vec(self) -> Vec<TenantComm> {
        self.per
    }
}

/// Per-link utilization summary over a simulated span.
#[derive(Debug, Clone)]
pub struct LinkUtilization {
    /// Utilization fraction per link index.
    pub per_link: Vec<f64>,
    pub mean: f64,
    pub peak: f64,
    /// Index of the most-utilized link.
    pub hottest: usize,
}

impl LinkUtilization {
    pub fn from_busy(busy: &[TimeNs], span: TimeNs) -> LinkUtilization {
        let span = span.max(1) as f64;
        let per_link: Vec<f64> = busy.iter().map(|&b| b as f64 / span).collect();
        let mean = per_link.iter().sum::<f64>() / per_link.len().max(1) as f64;
        let (hottest, peak) = per_link
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, &v)| (i, v))
            .unwrap_or((0, 0.0));
        LinkUtilization { per_link, mean, peak, hottest }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn energy_log_coalesces_within_a_bin_and_preserves_totals() {
        let mut log = EnergyLog::new(3);
        log.set_bin_ns(1_000);
        log.push(0, 10, 1.0);
        log.push(0, 900, 2.0); // same (node, bin) -> coalesces
        log.push(1, 950, 4.0); // other node -> own entry
        log.push(0, 1_010, 8.0); // next bin -> new entry
        let ev = log.drain();
        assert_eq!(ev.len(), 3);
        assert_eq!(ev[0], (0, 0, 3.0));
        assert_eq!(ev[1], (1, 0, 4.0));
        assert_eq!(ev[2], (0, 1_000, 8.0));
        assert_eq!(log.total_pj(), 15.0);
        // After a drain the node restarts a fresh entry even in-bin.
        log.push(0, 1_020, 16.0);
        assert_eq!(log.drain(), vec![(0, 1_000, 16.0)]);
    }

    #[test]
    fn energy_log_default_bin_merges_only_identical_timestamps() {
        let mut log = EnergyLog::new(1);
        log.push(0, 5, 1.0);
        log.push(0, 5, 1.0);
        log.push(0, 6, 1.0);
        assert_eq!(log.drain(), vec![(0, 5, 2.0), (0, 6, 1.0)]);
    }

    #[test]
    fn tenant_traffic_attributes_flows_densely() {
        let mut t = TenantTraffic::new();
        t.add_flow(2, 100, 3);
        t.add_flow(0, 50, 2);
        t.add_flow(2, 10, 1);
        let per = t.per_tenant();
        assert_eq!(per.len(), 3);
        assert_eq!(per[0], TenantComm { flows: 1, bytes: 50, byte_hops: 100 });
        assert_eq!(per[1], TenantComm::default());
        assert_eq!(per[2], TenantComm { flows: 2, bytes: 110, byte_hops: 310 });
        assert_eq!(t.into_vec().len(), 3);
    }
}
