//! Network-on-interposer simulator (our from-scratch HeteroGarnet analog).
//!
//! The inter-chiplet network is the *shared* resource of the co-simulation:
//! a single network simulation accounts for all active chiplet-to-chiplet
//! flows of all DNN models simultaneously, so contention between layer
//! traffic emerges from link arbitration rather than being post-hoc
//! estimated (paper §III-D/E).
//!
//! Two fidelity levels share the same [`topology::Topology`]:
//!
//! * [`engine::PacketEngine`] — event-driven virtual-cut-through model at
//!   packet (16-flit) granularity: per-link FIFO serialization, cut-through
//!   pipelining across hops, heterogeneous link widths/clocks.  Default —
//!   fast enough for the full 50-model experiments.
//! * [`flit::FlitEngine`] — cycle-driven wormhole model with per-port
//!   input buffers, credit flow control and round-robin switch allocation.
//!   Used for validation and small runs (`--noc flit`).
//!
//! Both implement [`NetworkSim`], the interface the Global Manager drives
//! in lockstep with the global event queue.

pub mod engine;
pub mod flit;
pub mod topology;

use crate::TimeNs;

/// Identifier of an injected flow (message).
pub type FlowId = u64;

/// A chiplet-to-chiplet activation transfer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FlowSpec {
    pub src: usize,
    pub dst: usize,
    pub bytes: u64,
}

/// A completed flow notification.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FlowCompletion {
    pub id: FlowId,
    pub time: TimeNs,
}

/// Per-flow statistics retained after completion.
#[derive(Debug, Clone, Copy)]
pub struct FlowStats {
    pub spec: FlowSpec,
    pub injected_ns: TimeNs,
    pub completed_ns: TimeNs,
    pub hops: u32,
}

impl FlowStats {
    pub fn latency_ns(&self) -> TimeNs {
        self.completed_ns - self.injected_ns
    }
}

/// Interface between the Global Manager and a network engine.
///
/// Contract: `advance_until(t)` simulates network activity up to *and
/// including* time `t` and returns the **earliest** not-yet-reported flow
/// completion with `time <= t`, or `None` once none remain.  The manager
/// calls it repeatedly before processing any global event at `t`, so flow
/// completions interleave correctly with compute events on the coherent
/// global timeline.
pub trait NetworkSim {
    /// Inject a flow at time `now` (must be >= all previously passed times).
    fn inject(&mut self, spec: FlowSpec, now: TimeNs) -> FlowId;
    /// Advance to `t`; return the earliest unreported completion <= t.
    fn advance_until(&mut self, t: TimeNs) -> Option<FlowCompletion>;
    /// True while any injected flow is still in flight.
    fn has_active(&self) -> bool;
    /// Stats for a completed flow.
    fn stats(&self, id: FlowId) -> Option<FlowStats>;
    /// Total dynamic NoI energy so far, pJ, and per-node attribution.
    fn comm_energy_pj(&self) -> f64;
    /// Drain (node, time, energy_pj) events accumulated since last call —
    /// consumed by the power tracker at 1 µs bins.
    fn drain_energy_events(&mut self) -> Vec<(usize, TimeNs, f64)>;
    /// Sum of flit-hops (or byte-hops) simulated — throughput metric.
    fn work_done(&self) -> u64;
    /// Cumulative busy time per link, ns (utilization = busy / span).
    /// Feeds the link-utilization statistics used for NoI bottleneck
    /// analysis (Fig. 7 root-causing) and DSE reports.
    fn link_busy_ns(&self) -> Vec<TimeNs> {
        Vec::new()
    }
}

/// Per-link utilization summary over a simulated span.
#[derive(Debug, Clone)]
pub struct LinkUtilization {
    /// Utilization fraction per link index.
    pub per_link: Vec<f64>,
    pub mean: f64,
    pub peak: f64,
    /// Index of the most-utilized link.
    pub hottest: usize,
}

impl LinkUtilization {
    pub fn from_busy(busy: &[TimeNs], span: TimeNs) -> LinkUtilization {
        let span = span.max(1) as f64;
        let per_link: Vec<f64> = busy.iter().map(|&b| b as f64 / span).collect();
        let mean = per_link.iter().sum::<f64>() / per_link.len().max(1) as f64;
        let (hottest, peak) = per_link
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, &v)| (i, v))
            .unwrap_or((0, 0.0));
        LinkUtilization { per_link, mean, peak, hottest }
    }
}
