//! Cycle-driven flit-level wormhole engine (validation fidelity).
//!
//! This is the closest analog to HeteroGarnet's router model that is
//! practical from scratch: per-input-port FIFO buffers, wormhole switching
//! (an output port stays bound to a packet from head to tail), credit-based
//! flow control (a flit only moves if the downstream buffer has a free
//! slot reserved at send time), and round-robin switch allocation.
//!
//! It shares `Topology` and packet segmentation with the default
//! [`super::engine::PacketEngine`]; integration tests assert the two agree
//! on uncontended latency to within the router-pipeline approximation and
//! rank contended flows identically.  Use `--noc flit` to select it; it is
//! O(cycles × links) and therefore reserved for small/validation runs.

use std::collections::{HashMap, VecDeque};

use super::topology::Topology;
use super::{FlowCompletion, FlowId, FlowSpec, FlowStats, NetworkSim};
use crate::TimeNs;

/// Input buffer depth in flits (per router input port).
const BUF_FLITS: usize = 8;
/// Flits per packet — must match the packet engine's segmentation.
const PACKET_FLITS: u64 = super::engine::PACKET_FLITS;

#[derive(Debug, Clone, Copy)]
struct Flit {
    flow: FlowId,
    /// Unique packet id (flow-local).
    pkt: u64,
    is_head: bool,
    is_tail: bool,
    dst: usize,
}

#[derive(Debug)]
struct InPort {
    buf: VecDeque<Flit>,
    /// Free slots not yet promised to an upstream sender.
    credits: usize,
}

impl InPort {
    fn new() -> Self {
        InPort { buf: VecDeque::with_capacity(BUF_FLITS), credits: BUF_FLITS }
    }
}

#[derive(Debug)]
struct FlowProgress {
    spec: FlowSpec,
    injected_ns: TimeNs,
    hops: u32,
    tails_left: u64,
}

/// The wormhole flit engine.
pub struct FlitEngine {
    topo: Topology,
    /// Per-link input port at the *destination* router of the link.
    ports: Vec<InPort>,
    /// Per-node local injection queue (treated as an extra input).
    inject_q: Vec<VecDeque<Flit>>,
    /// Output binding: link -> Some((source kind, packet uid)).
    /// source kind: usize::MAX..=usize::MAX-? we encode input as
    /// `InputRef::Link(l)` or `InputRef::Local(node)`.
    bound: Vec<Option<(InputRef, FlowId, u64)>>,
    /// Round-robin pointers per link (over candidate inputs).
    rr: Vec<usize>,
    /// Flits in flight over a link: (arrival_cycle, link, flit).
    in_flight: VecDeque<(u64, usize, Flit)>,
    flows: HashMap<FlowId, FlowProgress>,
    finished: HashMap<FlowId, FlowStats>,
    completions: VecDeque<(TimeNs, FlowId)>,
    next_flow_id: FlowId,
    cycle: u64,
    energy_events: Vec<(usize, TimeNs, f64)>,
    total_energy_pj: f64,
    work: u64,
    /// Cycles each link transferred a flit (busy accounting).
    link_busy_cycles: Vec<u64>,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum InputRef {
    /// Input buffer fed by a link (index).
    Link(usize),
    /// The node-local injection queue.
    Local(usize),
}

impl FlitEngine {
    pub fn new(topo: Topology) -> Self {
        for l in &topo.links {
            assert_eq!(l.clock_div, 1, "flit engine requires homogeneous clocks");
        }
        let nlinks = topo.links.len();
        let nnodes = topo.num_nodes;
        FlitEngine {
            ports: (0..nlinks).map(|_| InPort::new()).collect(),
            inject_q: vec![VecDeque::new(); nnodes],
            bound: vec![None; nlinks],
            rr: vec![0; nlinks],
            in_flight: VecDeque::new(),
            topo,
            flows: HashMap::new(),
            finished: HashMap::new(),
            completions: VecDeque::new(),
            next_flow_id: 0,
            cycle: 0,
            energy_events: Vec::new(),
            total_energy_pj: 0.0,
            work: 0,
            link_busy_cycles: vec![0; nlinks],
        }
    }

    fn ns(&self, cycle: u64) -> TimeNs {
        (cycle as f64 * self.topo.cycle_ns).round() as TimeNs
    }

    fn cycle_of(&self, t: TimeNs) -> u64 {
        (t as f64 / self.topo.cycle_ns).ceil() as u64
    }

    /// The output link a flit wants at router `node`.
    fn route_out(&self, node: usize, dst: usize) -> Option<usize> {
        if node == dst {
            None
        } else {
            Some(self.topo.route[node][dst])
        }
    }

    /// Candidate inputs of router `node`: all in-links plus local queue.
    fn inputs_of(&self, node: usize) -> Vec<InputRef> {
        let mut v: Vec<InputRef> = self
            .topo
            .links
            .iter()
            .enumerate()
            .filter(|(_, l)| l.dst == node)
            .map(|(i, _)| InputRef::Link(i))
            .collect();
        v.push(InputRef::Local(node));
        v
    }

    fn front(&self, input: InputRef) -> Option<&Flit> {
        match input {
            InputRef::Link(l) => self.ports[l].buf.front(),
            InputRef::Local(n) => self.inject_q[n].front(),
        }
    }

    fn pop(&mut self, input: InputRef) -> Flit {
        match input {
            InputRef::Link(l) => {
                let f = self.ports[l].buf.pop_front().unwrap();
                self.ports[l].credits += 1;
                f
            }
            InputRef::Local(n) => self.inject_q[n].pop_front().unwrap(),
        }
    }

    /// One router+link cycle.  Returns true if anything moved.
    fn step_cycle(&mut self) -> bool {
        let mut moved = false;
        self.cycle += 1;
        let now_ns = self.ns(self.cycle);

        // 1. Deliver flits whose link traversal finishes this cycle.
        while let Some(&(arr, link, flit)) = self.in_flight.front() {
            if arr > self.cycle {
                break;
            }
            self.in_flight.pop_front();
            let node = self.topo.links[link].dst;
            if flit.dst == node {
                // Ejection: leaves the network immediately; return credit.
                self.ports[link].credits += 1;
                if flit.is_tail {
                    self.finish_packet(flit, now_ns);
                }
            } else {
                self.ports[link].buf.push_back(flit);
            }
            moved = true;
        }

        // 2. Switch allocation + traversal per output link.
        for link in 0..self.topo.links.len() {
            // Allocate if free.
            if self.bound[link].is_none() {
                let node = self.topo.links[link].src;
                let inputs = self.inputs_of(node);
                let start = self.rr[link] % inputs.len();
                for k in 0..inputs.len() {
                    let input = inputs[(start + k) % inputs.len()];
                    if let Some(f) = self.front(input) {
                        if f.is_head && self.route_out(node, f.dst) == Some(link) {
                            self.bound[link] = Some((input, f.flow, f.pkt));
                            self.rr[link] = (start + k + 1) % inputs.len();
                            break;
                        }
                    }
                }
            }
            // Traverse one flit of the bound packet if credits allow.
            if let Some((input, flow, pkt)) = self.bound[link] {
                let ready = matches!(self.front(input), Some(f) if f.flow == flow && f.pkt == pkt);
                if ready {
                    // Need a downstream slot unless the flit will eject.
                    let downstream_dst = self.topo.links[link].dst;
                    let f = *self.front(input).unwrap();
                    let will_eject = f.dst == downstream_dst;
                    if will_eject || self.ports[link].credits > 0 {
                        let f = self.pop(input);
                        if !will_eject {
                            self.ports[link].credits -= 1;
                        }
                        let arrival = self.cycle + self.topo.hop_latency_cycles.max(1);
                        self.in_flight.push_back((arrival, link, f));
                        // Keep in_flight sorted by arrival (hop latency is
                        // constant, so push_back order is already sorted).
                        let l = &self.topo.links[link];
                        let pj = l.width_bytes as f64 * l.e_per_byte_pj;
                        self.energy_events.push((l.src, now_ns, pj));
                        self.total_energy_pj += pj;
                        self.work += l.width_bytes;
                        self.link_busy_cycles[link] += 1;
                        if f.is_tail {
                            self.bound[link] = None;
                        }
                        moved = true;
                    }
                }
            }
        }
        moved
    }

    fn finish_packet(&mut self, tail: Flit, now_ns: TimeNs) {
        let done = {
            let fp = self.flows.get_mut(&tail.flow).expect("tail for unknown flow");
            fp.tails_left -= 1;
            fp.tails_left == 0
        };
        if done {
            let fp = self.flows.remove(&tail.flow).unwrap();
            let stats = FlowStats {
                spec: fp.spec,
                injected_ns: fp.injected_ns,
                completed_ns: now_ns,
                hops: fp.hops,
            };
            self.finished.insert(tail.flow, stats);
            self.completions.push_back((now_ns, tail.flow));
        }
    }

    /// True if any flit anywhere is still queued/in flight.
    fn network_busy(&self) -> bool {
        !self.in_flight.is_empty()
            || self.ports.iter().any(|p| !p.buf.is_empty())
            || self.inject_q.iter().any(|q| !q.is_empty())
    }
}

impl NetworkSim for FlitEngine {
    fn inject(&mut self, spec: FlowSpec, now: TimeNs) -> FlowId {
        let id = self.next_flow_id;
        self.next_flow_id += 1;
        // Catch the engine's clock up to the injection time without
        // simulating idle cycles one by one.
        let inj_cycle = self.cycle_of(now);
        if !self.network_busy() && inj_cycle > self.cycle {
            self.cycle = inj_cycle;
        }
        let path = self.topo.path(spec.src, spec.dst);
        if path.is_empty() {
            let stats = FlowStats { spec, injected_ns: now, completed_ns: now, hops: 0 };
            self.finished.insert(id, stats);
            self.completions.push_back((now, id));
            return id;
        }
        let width = self.topo.links[path[0]].width_bytes;
        let payload_flits = spec.bytes.max(1).div_ceil(width);
        let npackets = payload_flits.div_ceil(PACKET_FLITS);
        self.flows.insert(
            id,
            FlowProgress { spec, injected_ns: now, hops: path.len() as u32, tails_left: npackets },
        );
        let mut remaining = payload_flits;
        for pkt in 0..npackets {
            let in_this = remaining.min(PACKET_FLITS);
            remaining -= in_this;
            for k in 0..in_this {
                self.inject_q[spec.src].push_back(Flit {
                    flow: id,
                    pkt,
                    is_head: k == 0,
                    is_tail: k == in_this - 1,
                    dst: spec.dst,
                });
            }
        }
        id
    }

    fn advance_until(&mut self, t: TimeNs) -> Option<FlowCompletion> {
        loop {
            if let Some(&(ct, _)) = self.completions.front() {
                if ct <= t {
                    let (time, id) = self.completions.pop_front().unwrap();
                    return Some(FlowCompletion { id, time });
                }
                return None;
            }
            if !self.network_busy() || self.ns(self.cycle) >= t {
                return None;
            }
            self.step_cycle();
        }
    }

    fn has_active(&self) -> bool {
        !self.flows.is_empty() || !self.completions.is_empty()
    }

    fn stats(&self, id: FlowId) -> Option<FlowStats> {
        self.finished.get(&id).copied()
    }

    fn comm_energy_pj(&self) -> f64 {
        self.total_energy_pj
    }

    fn drain_energy_events(&mut self) -> Vec<(usize, TimeNs, f64)> {
        std::mem::take(&mut self.energy_events)
    }

    fn work_done(&self) -> u64 {
        self.work
    }

    fn link_busy_ns(&self) -> Vec<TimeNs> {
        self.link_busy_cycles
            .iter()
            .map(|&c| (c as f64 * self.topo.cycle_ns).round() as TimeNs)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::LinkParams;
    use crate::noc::engine::PacketEngine;
    use crate::noc::topology::mesh;

    fn flit_engine(rows: usize, cols: usize) -> FlitEngine {
        FlitEngine::new(mesh(rows, cols, &LinkParams::default()))
    }

    fn complete_all(e: &mut dyn NetworkSim) -> Vec<FlowCompletion> {
        let mut v = Vec::new();
        while let Some(c) = e.advance_until(TimeNs::MAX) {
            v.push(c);
        }
        v
    }

    #[test]
    fn single_packet_single_hop() {
        let mut e = flit_engine(1, 2);
        let id = e.inject(FlowSpec { src: 0, dst: 1, bytes: 512 }, 0);
        let done = complete_all(&mut e);
        assert_eq!(done.len(), 1);
        let s = e.stats(id).unwrap();
        // 16 flits, 1 flit/cycle, 4-cycle hop latency: tail ejects around
        // cycle 16+4+O(1) — must be within a couple of cycles of the
        // packet engine's 20 ns.
        assert!((18..=24).contains(&s.latency_ns()), "{}", s.latency_ns());
    }

    #[test]
    fn wormhole_does_not_interleave_packets_on_a_link() {
        // Two flows share link 1->2 in a 1x3 line; with wormhole binding,
        // each packet transfers contiguously.  We just assert both finish
        // and the shared-link flow pair is slower than solo.
        let mut e = flit_engine(1, 3);
        e.inject(FlowSpec { src: 0, dst: 2, bytes: 2048 }, 0);
        e.inject(FlowSpec { src: 1, dst: 2, bytes: 2048 }, 0);
        let done = complete_all(&mut e);
        assert_eq!(done.len(), 2);

        let mut solo = flit_engine(1, 3);
        let sid = solo.inject(FlowSpec { src: 1, dst: 2, bytes: 2048 }, 0);
        complete_all(&mut solo);
        let solo_lat = solo.stats(sid).unwrap().latency_ns();
        assert!(done.iter().any(|c| {
            e.stats(c.id).unwrap().latency_ns() > solo_lat
        }));
    }

    #[test]
    fn agrees_with_packet_engine_on_uncontended_latency() {
        // Across several sizes/hop counts the two engines should agree to
        // within ~30% + a few cycles (router pipeline approximations).
        for (cols, bytes) in [(2usize, 512u64), (4, 2048), (6, 16384)] {
            let mut fe = flit_engine(1, cols);
            let fid = fe.inject(FlowSpec { src: 0, dst: cols - 1, bytes }, 0);
            complete_all(&mut fe);
            let fl = fe.stats(fid).unwrap().latency_ns() as f64;

            let mut pe = PacketEngine::new(mesh(1, cols, &LinkParams::default()));
            let pid = pe.inject(FlowSpec { src: 0, dst: cols - 1, bytes }, 0);
            while pe.advance_until(TimeNs::MAX).is_some() {}
            let pl = pe.stats(pid).unwrap().latency_ns() as f64;

            let ratio = fl / pl;
            assert!(
                (0.5..=1.6).contains(&ratio),
                "cols={cols} bytes={bytes}: flit={fl} packet={pl} ratio={ratio}"
            );
        }
    }

    #[test]
    fn injection_after_idle_fast_forwards() {
        let mut e = flit_engine(1, 2);
        let id = e.inject(FlowSpec { src: 0, dst: 1, bytes: 512 }, 1_000_000);
        let c = e.advance_until(TimeNs::MAX).unwrap();
        assert_eq!(c.id, id);
        assert!(c.time >= 1_000_000);
        let s = e.stats(id).unwrap();
        assert!(s.latency_ns() < 100);
    }

    #[test]
    fn credits_bound_buffer_occupancy() {
        // Saturating many flows through one column must not panic or leak:
        // buffer occupancy is bounded by construction; we just check
        // everything drains.
        let mut e = flit_engine(4, 4);
        for i in 0..12 {
            e.inject(FlowSpec { src: i % 4, dst: 12 + (i % 4), bytes: 4096 }, 0);
        }
        let done = complete_all(&mut e);
        assert_eq!(done.len(), 12);
        assert!(!e.has_active());
    }
}
