//! Cycle-driven flit-level wormhole engine (production-fast).
//!
//! This is the closest analog to HeteroGarnet's router model that is
//! practical from scratch: per-input-port FIFO buffers, wormhole switching
//! (an output port stays bound to a packet from head to tail), credit-based
//! flow control (a flit only moves if the downstream buffer has a free
//! slot reserved at send time), and round-robin switch allocation.
//!
//! It shares `Topology` and packet segmentation with the default
//! [`super::engine::PacketEngine`]; integration tests assert the two agree
//! on uncontended latency to within the router-pipeline approximation and
//! rank contended flows identically.  Select it with `--noc flit`.
//!
//! ## Active-set, cycle-skipping scheduler
//!
//! A naive cycle-driven engine costs O(cycles × links) — every link is
//! re-examined every cycle whether or not anything near it can move.  This
//! engine keeps the *exact* cycle-for-cycle semantics of that dense scan
//! (asserted byte-for-byte by the differential harness against the
//! reference implementation in `#[cfg(test)] mod reference`) while paying
//! only for actual traffic:
//!
//! * **Precomputed router inputs** — each router's candidate input list
//!   (in-links + local injection queue) comes from
//!   [`Topology::in_links`], built once at construction; the dense scan
//!   rebuilt it by filtering *all* links for *every* link each cycle,
//!   making a cycle O(links²).
//! * **Active set** — per-router counts of non-empty inputs select, each
//!   cycle, only the output links whose source router could possibly
//!   allocate or traverse.  A link whose router has no buffered flit is
//!   provably a no-op under the dense semantics (allocation scans empty
//!   fronts, traversal needs a front) and is skipped.  Candidates are
//!   processed in ascending link index, the dense scan's order, because
//!   intra-cycle pops are observable across links (credits and queue
//!   fronts).
//! * **Cycle skipping** — a cycle in which no flit moved leaves the
//!   switch state frozen (allocation-only cycles change `bound`/`rr` but
//!   cannot unblock themselves; credits only return on movement), so the
//!   engine jumps `cycle` straight to the next in-flight arrival instead
//!   of spinning once per empty cycle.
//! * **Flat state + coalesced energy** — per-flow state lives in a slab
//!   indexed by the sequential `FlowId` (the packet engine's §Perf
//!   lesson) and per-flit-hop energy folds into one
//!   [`super::EnergyLog`] entry per (node, power-bin).
//!
//! Cost therefore scales with flit-hops simulated, not with
//! `cycles × links`, making flit fidelity usable for full serving-scale
//! scenarios (see the `traffic-poisson-flit` / `dtm-ceiling-flit`
//! presets), not just validation runs.

use std::collections::{BTreeSet, HashMap, VecDeque};

use super::topology::Topology;
use super::{EnergyLog, FlowCompletion, FlowId, FlowSpec, FlowStats, LinkTraceEvent, NetworkSim};
use crate::TimeNs;

/// Default input buffer depth in flits (per router input port).
/// Shared with the sharded parallel engine (`crate::par`), which must
/// segment and buffer identically to stay byte-compatible.
pub(crate) const BUF_FLITS: usize = 8;
/// Flits per packet — must match the packet engine's segmentation.
pub(crate) const PACKET_FLITS: u64 = super::engine::PACKET_FLITS;

#[derive(Debug, Clone, Copy)]
pub(crate) struct Flit {
    pub(crate) flow: FlowId,
    /// Unique packet id (flow-local).
    pub(crate) pkt: u64,
    pub(crate) is_head: bool,
    pub(crate) is_tail: bool,
    pub(crate) dst: usize,
}

#[derive(Debug)]
pub(crate) struct InPort {
    pub(crate) buf: VecDeque<Flit>,
    /// Free slots not yet promised to an upstream sender.
    pub(crate) credits: usize,
}

impl InPort {
    pub(crate) fn new(depth: usize) -> Self {
        InPort { buf: VecDeque::with_capacity(depth), credits: depth }
    }
}

#[derive(Debug)]
pub(crate) struct FlowProgress {
    pub(crate) spec: FlowSpec,
    pub(crate) injected_ns: TimeNs,
    pub(crate) hops: u32,
    pub(crate) tails_left: u64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum InputRef {
    /// Input buffer fed by a link (index).
    Link(usize),
    /// The node-local injection queue.
    Local(usize),
}

/// The wormhole flit engine.
pub struct FlitEngine {
    topo: Topology,
    /// Per-link input port at the *destination* router of the link.
    ports: Vec<InPort>,
    /// Per-node local injection queue (treated as an extra input).
    inject_q: Vec<VecDeque<Flit>>,
    /// Output binding: link -> Some((input, flow, packet uid)).
    bound: Vec<Option<(InputRef, FlowId, u64)>>,
    /// Round-robin pointers per link (over candidate inputs).
    rr: Vec<usize>,
    /// Flits in flight over a link: (arrival_cycle, link, flit).  Hop
    /// latency is constant, so push order is already arrival order.
    in_flight: VecDeque<(u64, usize, Flit)>,
    /// Per-flow state, indexed by the sequential `FlowId` (slab — the
    /// per-flit HashMap lookup was a measurable cost, as it was in the
    /// packet engine).
    flows: Vec<Option<FlowProgress>>,
    active_flows: usize,
    finished: HashMap<FlowId, FlowStats>,
    completions: VecDeque<(TimeNs, FlowId)>,
    next_flow_id: FlowId,
    cycle: u64,
    energy: EnergyLog,
    work: u64,
    /// Cycles each link transferred a flit (busy accounting).
    link_busy_cycles: Vec<u64>,
    /// Candidate input lists per router: in-links (ascending link index)
    /// then the local injection queue — precomputed once.
    inputs: Vec<Vec<InputRef>>,
    /// Number of non-empty candidate inputs per router; a router with
    /// zero pending inputs cannot allocate or traverse any of its output
    /// links this cycle.
    pending_inputs: Vec<u32>,
    /// Total flits sitting in ports + injection queues (busy test).
    buffered: u64,
    /// Reusable scratch list of candidate links for the current cycle.
    candidates: Vec<usize>,
    /// Per-link occupancy log for the flight recorder, coalescing
    /// contiguous same-flow traversal cycles into one span; `None` (the
    /// default) keeps tracing entirely off the hot path.
    link_trace: Option<LinkTraceLog>,
}

/// Coalescing per-link occupancy log (flit traversal cycles -> spans).
/// Shared with `crate::par`, whose coordinator replays merged traversal
/// events through the identical coalescing for byte-identical traces.
#[derive(Debug, Default)]
pub(crate) struct LinkTraceLog {
    events: Vec<LinkTraceEvent>,
    /// Open span per link: (flow, first cycle, last cycle), where the
    /// span covers traversal cycles `first..=last`.
    open: Vec<Option<(FlowId, u64, u64)>>,
}

impl LinkTraceLog {
    pub(crate) fn new(nlinks: usize) -> LinkTraceLog {
        LinkTraceLog { events: Vec::new(), open: vec![None; nlinks] }
    }

    /// Record that `flow` traversed `link` during `cycle`.
    pub(crate) fn on_traverse(&mut self, link: usize, flow: FlowId, cycle: u64, cycle_ns: f64) {
        match &mut self.open[link] {
            Some((f, _, last)) if *f == flow && *last + 1 == cycle => *last = cycle,
            slot => {
                if let Some(span) = slot.take() {
                    self.events.push(Self::to_event(link, span, cycle_ns));
                }
                *slot = Some((flow, cycle, cycle));
            }
        }
    }

    /// Flush all open spans (drain boundary) and take the event log.
    pub(crate) fn drain(&mut self, cycle_ns: f64) -> Vec<LinkTraceEvent> {
        for (link, slot) in self.open.iter_mut().enumerate() {
            if let Some(span) = slot.take() {
                self.events.push(Self::to_event(link, span, cycle_ns));
            }
        }
        std::mem::take(&mut self.events)
    }

    fn to_event(
        link: usize,
        (flow, first, last): (FlowId, u64, u64),
        cycle_ns: f64,
    ) -> LinkTraceEvent {
        // A traversal during cycle `c` occupies (c-1, c] in wall time;
        // anchor both ends on the same rounding as `FlitEngine::ns` so
        // adjacent spans abut without overlapping.
        let start_ns = ((first - 1) as f64 * cycle_ns).round() as TimeNs;
        let end_ns = (last as f64 * cycle_ns).round() as TimeNs;
        LinkTraceEvent {
            link,
            flow,
            start_ns,
            dur_ns: end_ns.saturating_sub(start_ns).max(1),
            // Wormhole stalls are not attributable per-span here; the
            // recorder reports 0 and contention shows as span gaps.
            stall_ns: 0,
        }
    }
}

impl FlitEngine {
    pub fn new(topo: Topology) -> Self {
        Self::with_buffer_depth(topo, BUF_FLITS)
    }

    /// Construct with an explicit per-port buffer depth (flits).  The
    /// differential harness sweeps this; `new` uses [`BUF_FLITS`].
    pub fn with_buffer_depth(topo: Topology, buf_flits: usize) -> Self {
        for l in &topo.links {
            assert_eq!(l.clock_div, 1, "flit engine requires homogeneous clocks");
        }
        let depth = buf_flits.max(1);
        let nlinks = topo.links.len();
        let nnodes = topo.num_nodes;
        let inputs: Vec<Vec<InputRef>> = (0..nnodes)
            .map(|n| {
                let mut v: Vec<InputRef> =
                    topo.in_links[n].iter().map(|&l| InputRef::Link(l)).collect();
                v.push(InputRef::Local(n));
                v
            })
            .collect();
        FlitEngine {
            ports: (0..nlinks).map(|_| InPort::new(depth)).collect(),
            inject_q: vec![VecDeque::new(); nnodes],
            bound: vec![None; nlinks],
            rr: vec![0; nlinks],
            in_flight: VecDeque::new(),
            flows: Vec::new(),
            active_flows: 0,
            finished: HashMap::new(),
            completions: VecDeque::new(),
            next_flow_id: 0,
            cycle: 0,
            energy: EnergyLog::new(nnodes),
            work: 0,
            link_busy_cycles: vec![0; nlinks],
            inputs,
            pending_inputs: vec![0; nnodes],
            buffered: 0,
            candidates: Vec::new(),
            link_trace: None,
            topo,
        }
    }

    fn ns(&self, cycle: u64) -> TimeNs {
        (cycle as f64 * self.topo.cycle_ns).round() as TimeNs
    }

    /// Smallest cycle whose [`ns`](Self::ns) stamp is `>= t`.
    ///
    /// `ceil(t / cycle_ns)` alone disagrees with `ns`'s *rounding* for
    /// non-integer `cycle_ns`, so an injection fast-forward could land on
    /// a cycle stamped before the injection time (events appearing to
    /// precede their cause).  Anchoring on `ns` itself makes the pair
    /// consistent by construction for any clock.
    fn cycle_of(&self, t: TimeNs) -> u64 {
        let mut c = (t as f64 / self.topo.cycle_ns).ceil() as u64;
        while c > 0 && self.ns(c - 1) >= t {
            c -= 1;
        }
        while c < u64::MAX && self.ns(c) < t {
            c += 1;
        }
        c
    }

    /// Smallest cycle `>= self.cycle` whose stamp reaches `t` — where a
    /// per-cycle loop idling toward `t` would come to rest.
    fn first_cycle_at(&self, t: TimeNs) -> u64 {
        self.cycle.max(self.cycle_of(t))
    }

    /// The output link a flit wants at router `node`.
    fn route_out(&self, node: usize, dst: usize) -> Option<usize> {
        if node == dst {
            None
        } else {
            let l = self.topo.route[node][dst];
            debug_assert_ne!(
                l,
                usize::MAX,
                "stranded flit survived apply_fault: {node} -> {dst}"
            );
            Some(l)
        }
    }

    fn front(&self, input: InputRef) -> Option<&Flit> {
        match input {
            InputRef::Link(l) => self.ports[l].buf.front(),
            InputRef::Local(n) => self.inject_q[n].front(),
        }
    }

    fn pop(&mut self, input: InputRef) -> Flit {
        self.buffered -= 1;
        match input {
            InputRef::Link(l) => {
                let f = self.ports[l].buf.pop_front().unwrap();
                self.ports[l].credits += 1;
                if self.ports[l].buf.is_empty() {
                    self.pending_inputs[self.topo.links[l].dst] -= 1;
                }
                f
            }
            InputRef::Local(n) => {
                let f = self.inject_q[n].pop_front().unwrap();
                if self.inject_q[n].is_empty() {
                    self.pending_inputs[n] -= 1;
                }
                f
            }
        }
    }

    /// One router+link cycle.  Returns true if any flit moved.
    fn step_cycle(&mut self) -> bool {
        let mut moved = false;
        self.cycle += 1;
        let now_ns = self.ns(self.cycle);

        // 1. Deliver flits whose link traversal finishes this cycle.
        while let Some(&(arr, link, flit)) = self.in_flight.front() {
            if arr > self.cycle {
                break;
            }
            self.in_flight.pop_front();
            let node = self.topo.links[link].dst;
            if flit.dst == node {
                // Ejection: leaves the network immediately; return credit.
                self.ports[link].credits += 1;
                if flit.is_tail {
                    self.finish_packet(flit, now_ns);
                }
            } else {
                if self.ports[link].buf.is_empty() {
                    self.pending_inputs[node] += 1;
                }
                self.ports[link].buf.push_back(flit);
                self.buffered += 1;
            }
            moved = true;
        }

        // 2. Switch allocation + traversal, restricted to output links of
        // routers that hold at least one buffered flit.  Processed in
        // ascending link index — identical to the dense 0..links scan
        // with its no-op links removed.
        let mut cands = std::mem::take(&mut self.candidates);
        cands.clear();
        for n in 0..self.topo.num_nodes {
            if self.pending_inputs[n] > 0 {
                cands.extend_from_slice(&self.topo.out_links[n]);
            }
        }
        cands.sort_unstable();
        for &link in &cands {
            // Allocate if free.
            if self.bound[link].is_none() {
                let node = self.topo.links[link].src;
                let ninputs = self.inputs[node].len();
                let start = self.rr[link] % ninputs;
                for k in 0..ninputs {
                    let input = self.inputs[node][(start + k) % ninputs];
                    if let Some(f) = self.front(input) {
                        if f.is_head && self.route_out(node, f.dst) == Some(link) {
                            self.bound[link] = Some((input, f.flow, f.pkt));
                            self.rr[link] = (start + k + 1) % ninputs;
                            break;
                        }
                    }
                }
            }
            // Traverse one flit of the bound packet if credits allow.
            if let Some((input, flow, pkt)) = self.bound[link] {
                let ready = matches!(self.front(input), Some(f) if f.flow == flow && f.pkt == pkt);
                if ready {
                    // Need a downstream slot unless the flit will eject.
                    let downstream_dst = self.topo.links[link].dst;
                    let f = *self.front(input).unwrap();
                    let will_eject = f.dst == downstream_dst;
                    if will_eject || self.ports[link].credits > 0 {
                        let f = self.pop(input);
                        if !will_eject {
                            self.ports[link].credits -= 1;
                        }
                        let arrival = self.cycle + self.topo.hop_latency_cycles.max(1);
                        self.in_flight.push_back((arrival, link, f));
                        let l = &self.topo.links[link];
                        let pj = l.width_bytes as f64 * l.e_per_byte_pj;
                        self.energy.push(l.src, now_ns, pj);
                        crate::prof::count(crate::prof::Counter::FlitHops, 1);
                        self.work += l.width_bytes;
                        self.link_busy_cycles[link] += 1;
                        if let Some(log) = &mut self.link_trace {
                            log.on_traverse(link, f.flow, self.cycle, self.topo.cycle_ns);
                        }
                        if f.is_tail {
                            self.bound[link] = None;
                        }
                        moved = true;
                    }
                }
            }
        }
        self.candidates = cands;
        moved
    }

    fn finish_packet(&mut self, tail: Flit, now_ns: TimeNs) {
        let slot = &mut self.flows[tail.flow as usize];
        let fp = slot.as_mut().expect("tail for unknown flow");
        fp.tails_left -= 1;
        if fp.tails_left == 0 {
            let fp = slot.take().unwrap();
            self.active_flows -= 1;
            let stats = FlowStats {
                spec: fp.spec,
                injected_ns: fp.injected_ns,
                completed_ns: now_ns,
                hops: fp.hops,
            };
            self.finished.insert(tail.flow, stats);
            self.completions.push_back((now_ns, tail.flow));
        }
    }

    /// True if any flit anywhere is still queued/in flight.
    fn network_busy(&self) -> bool {
        !self.in_flight.is_empty() || self.buffered > 0
    }
}

impl NetworkSim for FlitEngine {
    fn inject(&mut self, spec: FlowSpec, now: TimeNs) -> FlowId {
        let id = self.next_flow_id;
        self.next_flow_id += 1;
        debug_assert_eq!(self.flows.len(), id as usize);
        // Catch the engine's clock up to the injection time without
        // simulating idle cycles one by one.
        let inj_cycle = self.cycle_of(now);
        if !self.network_busy() && inj_cycle > self.cycle {
            self.cycle = inj_cycle;
        }
        let path = self
            .topo
            .path(spec.src, spec.dst)
            .expect("inject: unreachable destination (check Topology::reachable first)");
        if path.is_empty() {
            let stats = FlowStats { spec, injected_ns: now, completed_ns: now, hops: 0 };
            self.flows.push(None);
            self.finished.insert(id, stats);
            self.completions.push_back((now, id));
            return id;
        }
        let width = self.topo.links[path[0]].width_bytes;
        let payload_flits = spec.bytes.max(1).div_ceil(width);
        let npackets = payload_flits.div_ceil(PACKET_FLITS);
        self.flows.push(Some(FlowProgress {
            spec,
            injected_ns: now,
            hops: path.len() as u32,
            tails_left: npackets,
        }));
        self.active_flows += 1;
        if self.inject_q[spec.src].is_empty() {
            self.pending_inputs[spec.src] += 1;
        }
        self.buffered += payload_flits;
        let mut remaining = payload_flits;
        for pkt in 0..npackets {
            let in_this = remaining.min(PACKET_FLITS);
            remaining -= in_this;
            for k in 0..in_this {
                self.inject_q[spec.src].push_back(Flit {
                    flow: id,
                    pkt,
                    is_head: k == 0,
                    is_tail: k == in_this - 1,
                    dst: spec.dst,
                });
            }
        }
        id
    }

    fn advance_until(&mut self, t: TimeNs) -> Option<FlowCompletion> {
        let _prof = crate::prof::scope(crate::prof::Subsystem::FlitEngine);
        loop {
            if let Some(&(ct, _)) = self.completions.front() {
                if ct <= t {
                    let (time, id) = self.completions.pop_front().unwrap();
                    return Some(FlowCompletion { id, time });
                }
                return None;
            }
            if !self.network_busy() || self.ns(self.cycle) >= t || self.cycle == u64::MAX {
                return None;
            }
            if !self.step_cycle() {
                // Nothing moved: the switch state is frozen until the
                // next in-flight arrival, so the intervening cycles are
                // provably no-ops — jump over them (bounded by where the
                // per-cycle loop would rest for this `t`).
                match self.in_flight.front() {
                    Some(&(arr, _, _)) if arr > self.cycle + 1 => {
                        self.cycle = (arr - 1).min(self.first_cycle_at(t));
                    }
                    Some(_) => {} // arrival due next cycle: nothing to skip
                    None => {
                        // Hard-blocked with nothing in flight: no state
                        // change is possible before new injections.
                        // Consume the requested horizon and yield.
                        self.cycle = self.first_cycle_at(t);
                        return None;
                    }
                }
            }
        }
    }

    fn has_active(&self) -> bool {
        self.active_flows > 0 || !self.completions.is_empty()
    }

    fn stats(&self, id: FlowId) -> Option<FlowStats> {
        self.finished.get(&id).copied()
    }

    fn comm_energy_pj(&self) -> f64 {
        self.energy.total_pj()
    }

    fn drain_energy_events(&mut self) -> Vec<(usize, TimeNs, f64)> {
        self.energy.drain()
    }

    fn set_energy_bin_ns(&mut self, bin_ns: TimeNs) {
        self.energy.set_bin_ns(bin_ns);
    }

    fn work_done(&self) -> u64 {
        self.work
    }

    fn link_busy_ns(&self) -> Vec<TimeNs> {
        self.link_busy_cycles
            .iter()
            .map(|&c| (c as f64 * self.topo.cycle_ns).round() as TimeNs)
            .collect()
    }

    fn set_link_trace(&mut self, enabled: bool) {
        self.link_trace =
            if enabled { Some(LinkTraceLog::new(self.topo.links.len())) } else { None };
    }

    fn drain_link_trace(&mut self) -> Vec<LinkTraceEvent> {
        match &mut self.link_trace {
            Some(log) => log.drain(self.topo.cycle_ns),
            None => Vec::new(),
        }
    }

    /// Adopt fault-aware route tables and drop every flow the failure
    /// touches.  Surviving flows reroute *adaptively*: each head flit
    /// consults the new tables at its next allocation, so traffic that
    /// never meets the dead links simply detours.  A flow is affected if
    /// any of its flits sits in a dead link's input port, is in flight
    /// over a dead link, holds a wormhole binding across one (body flits
    /// upstream would otherwise follow the head through it), or is
    /// stranded — parked at a router from which the new tables have no
    /// route to its destination.
    fn apply_fault(&mut self, topo: &Topology, link_down: &[bool]) -> Vec<(FlowId, FlowSpec)> {
        debug_assert_eq!(topo.links.len(), self.topo.links.len(), "same link universe");
        self.topo.route = topo.route.clone();
        self.topo.hop_table = topo.hop_table.clone();

        let route = &self.topo.route;
        let stranded = |node: usize, dst: usize| node != dst && route[node][dst] == usize::MAX;
        let mut affected: BTreeSet<FlowId> = BTreeSet::new();
        for (l, port) in self.ports.iter().enumerate() {
            for f in &port.buf {
                if link_down[l] || stranded(self.topo.links[l].dst, f.dst) {
                    affected.insert(f.flow);
                }
            }
        }
        for (n, q) in self.inject_q.iter().enumerate() {
            for f in q {
                if stranded(n, f.dst) {
                    affected.insert(f.flow);
                }
            }
        }
        for &(_, l, f) in &self.in_flight {
            if link_down[l] || stranded(self.topo.links[l].dst, f.dst) {
                affected.insert(f.flow);
            }
        }
        for (l, b) in self.bound.iter().enumerate() {
            if link_down[l] {
                if let Some((_, flow, _)) = b {
                    affected.insert(*flow);
                }
            }
        }
        if affected.is_empty() {
            return Vec::new();
        }

        // Purge every flit of every affected flow, restoring the credits
        // they hold: a buffered flit returns its own port slot; an
        // in-flight flit returns the downstream slot reserved at send
        // time (none was reserved for a flit about to eject).
        for port in self.ports.iter_mut() {
            let before = port.buf.len();
            port.buf.retain(|f| !affected.contains(&f.flow));
            let removed = before - port.buf.len();
            port.credits += removed;
            self.buffered -= removed as u64;
        }
        for q in self.inject_q.iter_mut() {
            let before = q.len();
            q.retain(|f| !affected.contains(&f.flow));
            self.buffered -= (before - q.len()) as u64;
        }
        let links = &self.topo.links;
        let mut returned: Vec<usize> = Vec::new();
        self.in_flight.retain(|&(_, l, f)| {
            if affected.contains(&f.flow) {
                if f.dst != links[l].dst {
                    returned.push(l);
                }
                false
            } else {
                true
            }
        });
        for l in returned {
            self.ports[l].credits += 1;
        }
        for b in self.bound.iter_mut() {
            if matches!(b, Some((_, flow, _)) if affected.contains(flow)) {
                *b = None;
            }
        }
        // Rebuild the active-set bookkeeping from surviving occupancy.
        for n in 0..self.topo.num_nodes {
            let in_bufs = self.topo.in_links[n]
                .iter()
                .filter(|&&l| !self.ports[l].buf.is_empty())
                .count();
            self.pending_inputs[n] = in_bufs as u32 + u32::from(!self.inject_q[n].is_empty());
        }
        let mut dropped = Vec::new();
        for id in affected {
            let fp = self.flows[id as usize].take().expect("affected flow exists");
            self.active_flows -= 1;
            dropped.push((id, fp.spec));
        }
        dropped
    }
}

/// The pre-rewrite dense-scan engine, kept verbatim (modulo the shared
/// `cycle_of` rounding fix) as the semantic reference for the
/// differential harness: every cycle it re-derives each router's input
/// list from the full link list and examines every link, and it books one
/// energy event per flit-hop.  O(cycles × links²) — test-only.
#[cfg(test)]
mod reference {
    use super::*;

    pub struct RefFlitEngine {
        topo: Topology,
        ports: Vec<InPort>,
        inject_q: Vec<VecDeque<Flit>>,
        bound: Vec<Option<(InputRef, FlowId, u64)>>,
        rr: Vec<usize>,
        in_flight: VecDeque<(u64, usize, Flit)>,
        flows: HashMap<FlowId, FlowProgress>,
        finished: HashMap<FlowId, FlowStats>,
        completions: VecDeque<(TimeNs, FlowId)>,
        next_flow_id: FlowId,
        cycle: u64,
        energy_events: Vec<(usize, TimeNs, f64)>,
        total_energy_pj: f64,
        work: u64,
        link_busy_cycles: Vec<u64>,
    }

    impl RefFlitEngine {
        pub fn with_buffer_depth(topo: Topology, buf_flits: usize) -> Self {
            let depth = buf_flits.max(1);
            let nlinks = topo.links.len();
            let nnodes = topo.num_nodes;
            RefFlitEngine {
                ports: (0..nlinks).map(|_| InPort::new(depth)).collect(),
                inject_q: vec![VecDeque::new(); nnodes],
                bound: vec![None; nlinks],
                rr: vec![0; nlinks],
                in_flight: VecDeque::new(),
                topo,
                flows: HashMap::new(),
                finished: HashMap::new(),
                completions: VecDeque::new(),
                next_flow_id: 0,
                cycle: 0,
                energy_events: Vec::new(),
                total_energy_pj: 0.0,
                work: 0,
                link_busy_cycles: vec![0; nlinks],
            }
        }

        fn ns(&self, cycle: u64) -> TimeNs {
            (cycle as f64 * self.topo.cycle_ns).round() as TimeNs
        }

        fn cycle_of(&self, t: TimeNs) -> u64 {
            let mut c = (t as f64 / self.topo.cycle_ns).ceil() as u64;
            while c > 0 && self.ns(c - 1) >= t {
                c -= 1;
            }
            while c < u64::MAX && self.ns(c) < t {
                c += 1;
            }
            c
        }

        fn route_out(&self, node: usize, dst: usize) -> Option<usize> {
            if node == dst {
                None
            } else {
                Some(self.topo.route[node][dst])
            }
        }

        /// Candidate inputs of router `node`, rebuilt from scratch —
        /// the allocation pattern the active-set rewrite removed.
        fn inputs_of(&self, node: usize) -> Vec<InputRef> {
            let mut v: Vec<InputRef> = self
                .topo
                .links
                .iter()
                .enumerate()
                .filter(|(_, l)| l.dst == node)
                .map(|(i, _)| InputRef::Link(i))
                .collect();
            v.push(InputRef::Local(node));
            v
        }

        fn front(&self, input: InputRef) -> Option<&Flit> {
            match input {
                InputRef::Link(l) => self.ports[l].buf.front(),
                InputRef::Local(n) => self.inject_q[n].front(),
            }
        }

        fn pop(&mut self, input: InputRef) -> Flit {
            match input {
                InputRef::Link(l) => {
                    let f = self.ports[l].buf.pop_front().unwrap();
                    self.ports[l].credits += 1;
                    f
                }
                InputRef::Local(n) => self.inject_q[n].pop_front().unwrap(),
            }
        }

        fn step_cycle(&mut self) -> bool {
            let mut moved = false;
            self.cycle += 1;
            let now_ns = self.ns(self.cycle);

            while let Some(&(arr, link, flit)) = self.in_flight.front() {
                if arr > self.cycle {
                    break;
                }
                self.in_flight.pop_front();
                let node = self.topo.links[link].dst;
                if flit.dst == node {
                    self.ports[link].credits += 1;
                    if flit.is_tail {
                        self.finish_packet(flit, now_ns);
                    }
                } else {
                    self.ports[link].buf.push_back(flit);
                }
                moved = true;
            }

            for link in 0..self.topo.links.len() {
                if self.bound[link].is_none() {
                    let node = self.topo.links[link].src;
                    let inputs = self.inputs_of(node);
                    let start = self.rr[link] % inputs.len();
                    for k in 0..inputs.len() {
                        let input = inputs[(start + k) % inputs.len()];
                        if let Some(f) = self.front(input) {
                            if f.is_head && self.route_out(node, f.dst) == Some(link) {
                                self.bound[link] = Some((input, f.flow, f.pkt));
                                self.rr[link] = (start + k + 1) % inputs.len();
                                break;
                            }
                        }
                    }
                }
                if let Some((input, flow, pkt)) = self.bound[link] {
                    let ready =
                        matches!(self.front(input), Some(f) if f.flow == flow && f.pkt == pkt);
                    if ready {
                        let downstream_dst = self.topo.links[link].dst;
                        let f = *self.front(input).unwrap();
                        let will_eject = f.dst == downstream_dst;
                        if will_eject || self.ports[link].credits > 0 {
                            let f = self.pop(input);
                            if !will_eject {
                                self.ports[link].credits -= 1;
                            }
                            let arrival = self.cycle + self.topo.hop_latency_cycles.max(1);
                            self.in_flight.push_back((arrival, link, f));
                            let l = &self.topo.links[link];
                            let pj = l.width_bytes as f64 * l.e_per_byte_pj;
                            self.energy_events.push((l.src, now_ns, pj));
                            self.total_energy_pj += pj;
                            self.work += l.width_bytes;
                            self.link_busy_cycles[link] += 1;
                            if f.is_tail {
                                self.bound[link] = None;
                            }
                            moved = true;
                        }
                    }
                }
            }
            moved
        }

        fn finish_packet(&mut self, tail: Flit, now_ns: TimeNs) {
            let done = {
                let fp = self.flows.get_mut(&tail.flow).expect("tail for unknown flow");
                fp.tails_left -= 1;
                fp.tails_left == 0
            };
            if done {
                let fp = self.flows.remove(&tail.flow).unwrap();
                let stats = FlowStats {
                    spec: fp.spec,
                    injected_ns: fp.injected_ns,
                    completed_ns: now_ns,
                    hops: fp.hops,
                };
                self.finished.insert(tail.flow, stats);
                self.completions.push_back((now_ns, tail.flow));
            }
        }

        fn network_busy(&self) -> bool {
            !self.in_flight.is_empty()
                || self.ports.iter().any(|p| !p.buf.is_empty())
                || self.inject_q.iter().any(|q| !q.is_empty())
        }
    }

    impl NetworkSim for RefFlitEngine {
        fn inject(&mut self, spec: FlowSpec, now: TimeNs) -> FlowId {
            let id = self.next_flow_id;
            self.next_flow_id += 1;
            let inj_cycle = self.cycle_of(now);
            if !self.network_busy() && inj_cycle > self.cycle {
                self.cycle = inj_cycle;
            }
            let path = self
                .topo
                .path(spec.src, spec.dst)
                .expect("inject: unreachable destination (check Topology::reachable first)");
            if path.is_empty() {
                let stats = FlowStats { spec, injected_ns: now, completed_ns: now, hops: 0 };
                self.finished.insert(id, stats);
                self.completions.push_back((now, id));
                return id;
            }
            let width = self.topo.links[path[0]].width_bytes;
            let payload_flits = spec.bytes.max(1).div_ceil(width);
            let npackets = payload_flits.div_ceil(PACKET_FLITS);
            self.flows.insert(
                id,
                FlowProgress {
                    spec,
                    injected_ns: now,
                    hops: path.len() as u32,
                    tails_left: npackets,
                },
            );
            let mut remaining = payload_flits;
            for pkt in 0..npackets {
                let in_this = remaining.min(PACKET_FLITS);
                remaining -= in_this;
                for k in 0..in_this {
                    self.inject_q[spec.src].push_back(Flit {
                        flow: id,
                        pkt,
                        is_head: k == 0,
                        is_tail: k == in_this - 1,
                        dst: spec.dst,
                    });
                }
            }
            id
        }

        fn advance_until(&mut self, t: TimeNs) -> Option<FlowCompletion> {
            loop {
                if let Some(&(ct, _)) = self.completions.front() {
                    if ct <= t {
                        let (time, id) = self.completions.pop_front().unwrap();
                        return Some(FlowCompletion { id, time });
                    }
                    return None;
                }
                if !self.network_busy() || self.ns(self.cycle) >= t {
                    return None;
                }
                self.step_cycle();
            }
        }

        fn has_active(&self) -> bool {
            !self.flows.is_empty() || !self.completions.is_empty()
        }

        fn stats(&self, id: FlowId) -> Option<FlowStats> {
            self.finished.get(&id).copied()
        }

        fn comm_energy_pj(&self) -> f64 {
            self.total_energy_pj
        }

        fn drain_energy_events(&mut self) -> Vec<(usize, TimeNs, f64)> {
            std::mem::take(&mut self.energy_events)
        }

        fn work_done(&self) -> u64 {
            self.work
        }

        fn link_busy_ns(&self) -> Vec<TimeNs> {
            self.link_busy_cycles
                .iter()
                .map(|&c| (c as f64 * self.topo.cycle_ns).round() as TimeNs)
                .collect()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::LinkParams;
    use crate::noc::engine::PacketEngine;
    use crate::noc::topology::{custom, mesh};
    use crate::util::rng::Rng;

    fn flit_engine(rows: usize, cols: usize) -> FlitEngine {
        FlitEngine::new(mesh(rows, cols, &LinkParams::default()))
    }

    fn complete_all(e: &mut dyn NetworkSim) -> Vec<FlowCompletion> {
        let mut v = Vec::new();
        while let Some(c) = e.advance_until(TimeNs::MAX) {
            v.push(c);
        }
        v
    }

    #[test]
    fn single_packet_single_hop() {
        let mut e = flit_engine(1, 2);
        let id = e.inject(FlowSpec { src: 0, dst: 1, bytes: 512 }, 0);
        let done = complete_all(&mut e);
        assert_eq!(done.len(), 1);
        let s = e.stats(id).unwrap();
        // 16 flits, 1 flit/cycle, 4-cycle hop latency: tail ejects around
        // cycle 16+4+O(1) — must be within a couple of cycles of the
        // packet engine's 20 ns.
        assert!((18..=24).contains(&s.latency_ns()), "{}", s.latency_ns());
    }

    #[test]
    fn link_trace_coalesces_and_covers_busy_time() {
        let mut e = flit_engine(1, 3);
        e.set_link_trace(true);
        let id = e.inject(FlowSpec { src: 0, dst: 2, bytes: 2048 }, 0);
        complete_all(&mut e);
        let trace = e.drain_link_trace();
        assert!(!trace.is_empty());
        assert!(trace.iter().all(|t| t.flow == id && t.dur_ns > 0));
        // Spans on one link never overlap, and their total matches the
        // busy-cycle accounting (same ns rounding) to within rounding.
        let busy = e.link_busy_ns();
        for (link, &b) in busy.iter().enumerate() {
            let mut spans: Vec<_> =
                trace.iter().filter(|t| t.link == link).collect();
            spans.sort_by_key(|t| t.start_ns);
            for w in spans.windows(2) {
                assert!(w[0].start_ns + w[0].dur_ns <= w[1].start_ns);
            }
            let traced: TimeNs = spans.iter().map(|t| t.dur_ns).sum();
            let slack = spans.len() as TimeNs + 1;
            assert!(traced.abs_diff(b) <= slack, "link {link}: {traced} vs {b}");
        }
        assert!(e.drain_link_trace().is_empty());
    }

    #[test]
    fn wormhole_does_not_interleave_packets_on_a_link() {
        // Two flows share link 1->2 in a 1x3 line; with wormhole binding,
        // each packet transfers contiguously.  We just assert both finish
        // and the shared-link flow pair is slower than solo.
        let mut e = flit_engine(1, 3);
        e.inject(FlowSpec { src: 0, dst: 2, bytes: 2048 }, 0);
        e.inject(FlowSpec { src: 1, dst: 2, bytes: 2048 }, 0);
        let done = complete_all(&mut e);
        assert_eq!(done.len(), 2);

        let mut solo = flit_engine(1, 3);
        let sid = solo.inject(FlowSpec { src: 1, dst: 2, bytes: 2048 }, 0);
        complete_all(&mut solo);
        let solo_lat = solo.stats(sid).unwrap().latency_ns();
        assert!(done.iter().any(|c| {
            e.stats(c.id).unwrap().latency_ns() > solo_lat
        }));
    }

    #[test]
    fn agrees_with_packet_engine_on_uncontended_latency() {
        // Across several sizes/hop counts the two engines should agree to
        // within ~30% + a few cycles (router pipeline approximations).
        for (cols, bytes) in [(2usize, 512u64), (4, 2048), (6, 16384)] {
            let mut fe = flit_engine(1, cols);
            let fid = fe.inject(FlowSpec { src: 0, dst: cols - 1, bytes }, 0);
            complete_all(&mut fe);
            let fl = fe.stats(fid).unwrap().latency_ns() as f64;

            let mut pe = PacketEngine::new(mesh(1, cols, &LinkParams::default()));
            let pid = pe.inject(FlowSpec { src: 0, dst: cols - 1, bytes }, 0);
            while pe.advance_until(TimeNs::MAX).is_some() {}
            let pl = pe.stats(pid).unwrap().latency_ns() as f64;

            let ratio = fl / pl;
            assert!(
                (0.5..=1.6).contains(&ratio),
                "cols={cols} bytes={bytes}: flit={fl} packet={pl} ratio={ratio}"
            );
        }
    }

    #[test]
    fn injection_after_idle_fast_forwards() {
        let mut e = flit_engine(1, 2);
        let id = e.inject(FlowSpec { src: 0, dst: 1, bytes: 512 }, 1_000_000);
        let c = e.advance_until(TimeNs::MAX).unwrap();
        assert_eq!(c.id, id);
        assert!(c.time >= 1_000_000);
        let s = e.stats(id).unwrap();
        assert!(s.latency_ns() < 100);
    }

    #[test]
    fn credits_bound_buffer_occupancy() {
        // Saturating many flows through one column must not panic or leak:
        // buffer occupancy is bounded by construction; we just check
        // everything drains.
        let mut e = flit_engine(4, 4);
        for i in 0..12 {
            e.inject(FlowSpec { src: i % 4, dst: 12 + (i % 4), bytes: 4096 }, 0);
        }
        let done = complete_all(&mut e);
        assert_eq!(done.len(), 12);
        assert!(!e.has_active());
    }

    #[test]
    fn cycle_skipping_crosses_long_gaps_cheaply() {
        // A flow injected after a huge idle gap, then another one later:
        // both must complete with small latencies and the engine must not
        // spin through the gap (this test would take minutes per-cycle).
        let mut e = flit_engine(1, 2);
        let a = e.inject(FlowSpec { src: 0, dst: 1, bytes: 512 }, 0);
        assert!(e.advance_until(10_000).is_some());
        let b = e.inject(FlowSpec { src: 0, dst: 1, bytes: 512 }, 40_000_000_000);
        let c = e.advance_until(TimeNs::MAX).unwrap();
        assert_eq!(c.id, b);
        assert!(e.stats(a).unwrap().latency_ns() < 100);
        assert!(e.stats(b).unwrap().latency_ns() < 100);
        assert!(e.stats(b).unwrap().completed_ns >= 40_000_000_000);
    }

    #[test]
    fn ns_and_cycle_of_agree_on_the_boundary() {
        // For any clock, cycle_of(t) is the first cycle whose ns() stamp
        // reaches t — never one early (the round-vs-ceil asymmetry).
        for ghz in [1.0, 0.5, 2.0, 3.0, 0.8, 1.6] {
            let p = LinkParams { clock_ghz: ghz, ..LinkParams::default() };
            let e = FlitEngine::new(mesh(1, 2, &p));
            for t in 0..500u64 {
                let c = e.cycle_of(t);
                assert!(
                    e.ns(c) >= t,
                    "ghz={ghz} t={t}: cycle_of={c} stamps at {} (< t: one cycle early)",
                    e.ns(c)
                );
                if c > 0 {
                    assert!(
                        e.ns(c - 1) < t,
                        "ghz={ghz} t={t}: cycle_of={c} is not minimal (ns({})={})",
                        c - 1,
                        e.ns(c - 1)
                    );
                }
            }
        }
    }

    #[test]
    fn injection_timestamps_never_precede_injection() {
        // Non-integer cycle_ns (1.6 GHz -> 0.625 ns/cy): a flow injected
        // at an off-grid time must not complete with a stamp implying it
        // started a cycle early.
        let p = LinkParams { clock_ghz: 1.6, ..LinkParams::default() };
        for t in [1u64, 3, 7, 13, 101, 1_001, 99_999] {
            let mut e = FlitEngine::new(mesh(1, 2, &p));
            let id = e.inject(FlowSpec { src: 0, dst: 1, bytes: 64 }, t);
            complete_all(&mut e);
            let s = e.stats(id).unwrap();
            assert!(s.completed_ns >= s.injected_ns, "t={t}: {s:?}");
        }
    }

    #[test]
    fn apply_fault_drops_crossing_flows_and_adopts_reroutes() {
        let p = LinkParams::default();
        let pristine = mesh(2, 2, &p);
        let mut e = FlitEngine::new(pristine.clone());
        // X-Y routing sends 0 -> 3 through node 1; 2 -> 3 stays clear.
        let crossing = e.inject(FlowSpec { src: 0, dst: 3, bytes: 4096 }, 0);
        let bystander = e.inject(FlowSpec { src: 2, dst: 3, bytes: 512 }, 0);
        // A few cycles so the crossing flow has flits on the wire.
        e.advance_until(5);
        let dead: Vec<bool> = pristine
            .links
            .iter()
            .map(|l| (l.src == 0 && l.dst == 1) || (l.src == 1 && l.dst == 0))
            .collect();
        let mut masked = pristine.clone();
        masked.apply_link_mask(&dead);
        assert_eq!(masked.hops(0, 3), Some(2));
        let dropped = e.apply_fault(&masked, &dead);
        assert_eq!(dropped.len(), 1);
        assert_eq!(dropped[0].0, crossing);
        assert_eq!(dropped[0].1.bytes, 4096);
        // The bystander still completes, and a retransmission detours
        // through node 2 under the adopted tables.
        let retry = e.inject(dropped[0].1, 100);
        let done = complete_all(&mut e);
        assert!(done.iter().any(|c| c.id == bystander));
        assert!(done.iter().any(|c| c.id == retry));
        assert_eq!(e.stats(retry).unwrap().hops, 2);
        assert!(!e.has_active());
    }

    #[test]
    fn apply_fault_with_no_dead_links_is_invisible() {
        let p = LinkParams::default();
        let topo = mesh(2, 2, &p);
        let mut a = FlitEngine::new(topo.clone());
        let mut b = FlitEngine::new(topo.clone());
        for e in [&mut a, &mut b] {
            e.inject(FlowSpec { src: 0, dst: 3, bytes: 2048 }, 0);
            e.inject(FlowSpec { src: 1, dst: 2, bytes: 1024 }, 3);
            e.advance_until(7);
        }
        let dropped = b.apply_fault(&topo, &vec![false; topo.links.len()]);
        assert!(dropped.is_empty());
        let da: Vec<_> = complete_all(&mut a).iter().map(|c| (c.id, c.time)).collect();
        let db: Vec<_> = complete_all(&mut b).iter().map(|c| (c.id, c.time)).collect();
        assert_eq!(da, db);
        assert_eq!(a.work_done(), b.work_done());
    }

    // ---------------------------------------------- differential harness

    /// A pre-generated drive schedule, replayed identically on both
    /// engines.
    #[derive(Debug, Clone)]
    enum Op {
        Inject(FlowSpec, TimeNs),
        Advance(TimeNs),
    }

    fn run_script(e: &mut dyn NetworkSim, script: &[Op]) -> Vec<(FlowId, TimeNs)> {
        let mut out = Vec::new();
        for op in script {
            match *op {
                Op::Inject(spec, at) => {
                    e.inject(spec, at);
                }
                Op::Advance(t) => {
                    while let Some(c) = e.advance_until(t) {
                        out.push((c.id, c.time));
                    }
                }
            }
        }
        // Drain to completion.
        while let Some(c) = e.advance_until(TimeNs::MAX) {
            out.push((c.id, c.time));
        }
        out
    }

    /// Random script: monotone injection times with bounded advances in
    /// between (exercising fast-forward, bounded advancement, and the
    /// cycle-skip path).
    fn random_script(rng: &mut Rng, nodes: usize, nflows: usize) -> Vec<Op> {
        let mut script = Vec::new();
        let mut t = 0u64;
        for _ in 0..nflows {
            t += rng.below(30_000);
            let src = rng.below_usize(nodes);
            // dst may equal src (empty-path flows complete instantly).
            let dst = rng.below_usize(nodes);
            let bytes = 1 + rng.below(16_384);
            script.push(Op::Inject(FlowSpec { src, dst, bytes }, t));
            if rng.below(3) == 0 {
                script.push(Op::Advance(t + rng.below(5_000)));
            }
        }
        script
    }

    fn assert_engines_match(
        mut new_engine: FlitEngine,
        mut ref_engine: reference::RefFlitEngine,
        script: &[Op],
        label: &str,
    ) {
        let got = run_script(&mut new_engine, script);
        let want = run_script(&mut ref_engine, script);
        assert_eq!(got, want, "{label}: completion sequences diverge");
        for &(id, _) in &want {
            assert_eq!(
                new_engine.stats(id),
                ref_engine.stats(id),
                "{label}: FlowStats diverge for flow {id}"
            );
        }
        assert_eq!(
            new_engine.comm_energy_pj().to_bits(),
            ref_engine.comm_energy_pj().to_bits(),
            "{label}: energy totals diverge ({} vs {})",
            new_engine.comm_energy_pj(),
            ref_engine.comm_energy_pj()
        );
        assert_eq!(
            new_engine.work_done(),
            ref_engine.work_done(),
            "{label}: work diverges"
        );
        assert_eq!(
            new_engine.link_busy_ns(),
            ref_engine.link_busy_ns(),
            "{label}: link busy accounting diverges"
        );
        // Coalesced events must sum to the reference's per-hop events.
        let sum = |ev: Vec<(usize, TimeNs, f64)>| -> f64 {
            ev.into_iter().map(|(_, _, pj)| pj).sum()
        };
        let a = sum(new_engine.drain_energy_events());
        let b = sum(ref_engine.drain_energy_events());
        assert!(
            (a - b).abs() <= 1e-9 * b.abs().max(1.0),
            "{label}: drained energy diverges: {a} vs {b}"
        );
    }

    #[test]
    fn differential_randomized_meshes_match_reference() {
        for seed in 0..6u64 {
            let mut rng = Rng::new(0xF117 + seed);
            let rows = 2 + rng.below_usize(3);
            let cols = 2 + rng.below_usize(3);
            let depth = [1, 2, 4, 8, 16][rng.below_usize(5)];
            let nflows = 2 + rng.below_usize(9);
            let p = LinkParams::default();
            let topo = mesh(rows, cols, &p);
            let script = random_script(&mut rng, rows * cols, nflows);
            assert_engines_match(
                FlitEngine::with_buffer_depth(topo.clone(), depth),
                reference::RefFlitEngine::with_buffer_depth(topo, depth),
                &script,
                &format!("mesh {rows}x{cols} depth={depth} seed={seed}"),
            );
        }
    }

    #[test]
    fn differential_non_integer_clock_matches_reference() {
        // 1.6 GHz and 3 GHz clocks: the ns/cycle_of rounding interplay
        // must stay identical through fast-forward and cycle skips.
        for (seed, ghz) in [(0u64, 1.6f64), (1, 3.0), (2, 0.8)] {
            let mut rng = Rng::new(0xC10C + seed);
            let p = LinkParams { clock_ghz: ghz, ..LinkParams::default() };
            let topo = mesh(2, 3, &p);
            let script = random_script(&mut rng, 6, 8);
            assert_engines_match(
                FlitEngine::new(topo.clone()),
                reference::RefFlitEngine::with_buffer_depth(topo, 8),
                &script,
                &format!("clock {ghz} GHz seed={seed}"),
            );
        }
    }

    #[test]
    fn differential_custom_line_matches_reference() {
        // A long line stresses wormhole chaining across many hops, and a
        // tiny buffer stresses credit stalls (the cycle-skip trigger).
        let mut rng = Rng::new(0x11E);
        let p = LinkParams::default();
        let topo = custom(7, &[(0, 1), (1, 2), (2, 3), (3, 4), (4, 5), (5, 6)], &p);
        for depth in [1usize, 2, 8] {
            let script = random_script(&mut rng, 7, 8);
            assert_engines_match(
                FlitEngine::with_buffer_depth(topo.clone(), depth),
                reference::RefFlitEngine::with_buffer_depth(topo.clone(), depth),
                &script,
                &format!("line depth={depth}"),
            );
        }
    }

    #[test]
    fn differential_bursty_same_destination_matches_reference() {
        // Hot-spot traffic: everything converges on one corner, maximizing
        // allocation contention and rr-pointer churn.
        let p = LinkParams::default();
        let topo = mesh(3, 3, &p);
        let mut script = Vec::new();
        for i in 0..8usize {
            script.push(Op::Inject(
                FlowSpec { src: i, dst: 8, bytes: 2_048 + 512 * i as u64 },
                (i as u64) * 7,
            ));
        }
        script.push(Op::Advance(100));
        script.push(Op::Advance(1_000));
        assert_engines_match(
            FlitEngine::new(topo.clone()),
            reference::RefFlitEngine::with_buffer_depth(topo, 8),
            &script,
            "hot-spot 3x3",
        );
    }
}
