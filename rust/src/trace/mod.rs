//! Flight recorder: request-lifecycle tracing, Perfetto export, and
//! latency breakdown.
//!
//! A [`TraceRecorder`] is a deterministic, bounded-memory event buffer
//! threaded through every layer of the co-simulation: global arrival →
//! dispatch queue → mapping attempts → per-layer compute on each chiplet
//! (with the DVFS level in effect) → NoI transfers (with per-link
//! stall/contention detail) → completion or drop, plus per-window gauges
//! (queue depth, busy chiplets, sensor temperature, governor state).
//!
//! ## Trace format
//!
//! [`TraceRecorder::export`] emits the Chrome trace-event JSON format
//! (`{"traceEvents": [...]}`) loadable in Perfetto
//! (<https://ui.perfetto.dev>) or `chrome://tracing`.  Tracks map onto
//! the format's process/thread ids:
//!
//! | pid (`+ replica × PID_STRIDE`) | process          | tid          |
//! |--------------------------------|------------------|--------------|
//! | [`PID_CHIPLET`]                | board compute    | chiplet id   |
//! | [`PID_NOI`]                    | NoI links        | link id      |
//! | [`PID_REQUEST`]                | request lifecycle| tenant id    |
//! | [`PID_GAUGE`]                  | gauges/counters  | 0            |
//!
//! Requests are async `b`/`n`/`e` events keyed by request id, so every
//! request reaches exactly one terminal state (`finish`, `drop`, or
//! `truncated` when the run ends mid-flight).  Compute and link
//! transfers are complete (`X`) spans; gauges are counter (`C`) series.
//!
//! ## Determinism and overhead
//!
//! Recording never consults wall-clock time or unordered maps, so a
//! trace is byte-identical for a given seed and configuration.  The ring
//! buffer is capped at [`TraceConfig::capacity`] events; overflow evicts
//! the oldest event and counts it in `otherData.dropped_events`.
//! Tracing is **off by default**: the hot-path hooks cost one
//! `Option::is_some` branch when disabled, and the `trace` cargo feature
//! (on by default) can compile even that out.
//!
//! ## Latency breakdown
//!
//! [`BreakdownAcc`] accumulates per-request interval evidence and
//! [`BreakdownAcc::finish`] converts it into a [`LatencyBreakdown`]
//! whose six components **sum exactly** to the end-to-end latency:
//!
//! * `dispatch_queue` — arrival until the model is mapped (admission,
//!   mapping retries, fleet dispatch buffering);
//! * `mapping_wait` — post-admission stall where neither compute nor
//!   communication of this request made progress (chiplet queueing,
//!   pipeline-credit waits);
//! * `compute` — union of compute spans, net of throttling;
//! * `dtm_throttle` — extra compute time attributable to DVFS scaling;
//! * `noi_serialization` — zero-contention transfer time (hops + wire);
//! * `noi_contention` — communication time beyond the ideal (queueing on
//!   links, fabric interference).
//!
//! Overlapping work is attributed once (compute wins over communication,
//! matching the pipelining model), so the components partition the
//! request's lifetime.  [`BreakdownStats`] aggregates breakdowns into
//! per-component p50/p99 histograms for `TrafficReport`/`FleetReport`.

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::sync::{Arc, Mutex};

use crate::serving::slo::LatencyHistogram;
use crate::util::json::Value;
use crate::TimeNs;

/// Process-id of the per-chiplet compute tracks.
pub const PID_CHIPLET: u32 = 1;
/// Process-id of the per-NoI-link transfer tracks.
pub const PID_NOI: u32 = 2;
/// Process-id of the request-lifecycle (async) tracks, one per tenant.
pub const PID_REQUEST: u32 = 3;
/// Process-id of the gauge/counter tracks.
pub const PID_GAUGE: u32 = 4;
/// Process-id of the fault-injection instant track.
pub const PID_FAULT: u32 = 5;
/// Pid stride between replica boards in a merged fleet trace.
pub const PID_STRIDE: u32 = 8;

/// Bitmask of event categories a recorder accepts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceCategories(u32);

impl TraceCategories {
    /// Request lifecycle: arrival, map, retries, finish, drop.
    pub const REQUEST: TraceCategories = TraceCategories(1);
    /// Per-layer compute spans on chiplet tracks.
    pub const COMPUTE: TraceCategories = TraceCategories(1 << 1);
    /// NoI flow and per-link transfer spans.
    pub const NOI: TraceCategories = TraceCategories(1 << 2);
    /// Thermal/governor instants and spans.
    pub const DTM: TraceCategories = TraceCategories(1 << 3);
    /// Periodic counter series (queue depth, busy chiplets, temps).
    pub const GAUGES: TraceCategories = TraceCategories(1 << 4);
    /// Fleet-level events (dispatch, autoscale, migration).
    pub const FLEET: TraceCategories = TraceCategories(1 << 5);
    /// Fault-injection instants (failures and repairs).
    pub const FAULT: TraceCategories = TraceCategories(1 << 6);

    const NAMES: [(&'static str, TraceCategories); 7] = [
        ("request", TraceCategories::REQUEST),
        ("compute", TraceCategories::COMPUTE),
        ("noi", TraceCategories::NOI),
        ("dtm", TraceCategories::DTM),
        ("gauges", TraceCategories::GAUGES),
        ("fleet", TraceCategories::FLEET),
        ("fault", TraceCategories::FAULT),
    ];

    /// Every category.
    pub fn all() -> TraceCategories {
        TraceCategories(0x7F)
    }

    /// No category (records nothing).
    pub fn none() -> TraceCategories {
        TraceCategories(0)
    }

    /// Union of two masks.
    pub fn with(self, other: TraceCategories) -> TraceCategories {
        TraceCategories(self.0 | other.0)
    }

    /// True when every bit of `other` is enabled in `self`.
    pub fn contains(self, other: TraceCategories) -> bool {
        self.0 & other.0 == other.0
    }

    /// Parse a comma-separated filter like `"request,compute,noi"`.
    /// `"all"` enables everything.
    pub fn parse(s: &str) -> anyhow::Result<TraceCategories> {
        let mut out = TraceCategories::none();
        for tok in s.split(',') {
            let tok = tok.trim().to_ascii_lowercase();
            if tok.is_empty() {
                continue;
            }
            if tok == "all" {
                return Ok(TraceCategories::all());
            }
            match Self::NAMES.iter().find(|(n, _)| *n == tok) {
                Some((_, c)) => out = out.with(*c),
                None => anyhow::bail!(
                    "unknown trace category '{tok}' (expected one of: all, request, \
                     compute, noi, dtm, gauges, fleet, fault)"
                ),
            }
        }
        Ok(out)
    }

    /// Canonical label of a single-bit category (export `cat` field).
    fn label(self) -> &'static str {
        Self::NAMES
            .iter()
            .find(|(_, c)| c.0 == self.0)
            .map(|(n, _)| *n)
            .unwrap_or("trace")
    }
}

/// Runtime tracing configuration.
#[derive(Debug, Clone)]
pub struct TraceConfig {
    /// Category filter; defaults to [`TraceCategories::all`].
    pub categories: TraceCategories,
    /// Ring-buffer capacity in events; overflow evicts oldest.
    pub capacity: usize,
    /// Gauge sampling cadence in sim-ns.
    pub gauge_ns: TimeNs,
    /// Derive a [`LatencyBreakdown`] per completed request.
    pub breakdown: bool,
}

impl Default for TraceConfig {
    fn default() -> TraceConfig {
        TraceConfig {
            categories: TraceCategories::all(),
            capacity: 1 << 20,
            gauge_ns: 100_000,
            breakdown: true,
        }
    }
}

impl TraceConfig {
    /// Restrict recording to `cats`.
    pub fn categories(mut self, cats: TraceCategories) -> TraceConfig {
        self.categories = cats;
        self
    }

    /// Cap the ring buffer at `cap` events.
    pub fn capacity(mut self, cap: usize) -> TraceConfig {
        self.capacity = cap.max(1);
        self
    }

    /// Sample gauges every `ns` of sim time.
    pub fn gauge_ns(mut self, ns: TimeNs) -> TraceConfig {
        self.gauge_ns = ns.max(1);
        self
    }

    /// Enable/disable per-request latency breakdown derivation.
    pub fn breakdown(mut self, on: bool) -> TraceConfig {
        self.breakdown = on;
        self
    }
}

/// Event phase (subset of the Chrome trace-event phases we emit).
#[derive(Debug, Clone)]
enum Ph {
    /// Complete span ("X") with a duration.
    Span { dur: TimeNs },
    /// Thread-scoped instant ("i").
    Instant,
    /// Counter sample ("C"); the series live in `args`.
    Counter,
    /// Async begin ("b") keyed by id.
    AsyncBegin { id: u64 },
    /// Async instant ("n") keyed by id.
    AsyncInstant { id: u64 },
    /// Async end ("e") keyed by id.
    AsyncEnd { id: u64 },
}

#[derive(Debug, Clone)]
struct Rec {
    ts: TimeNs,
    pid: u32,
    tid: u32,
    cat: &'static str,
    name: String,
    ph: Ph,
    args: Vec<(&'static str, Value)>,
}

/// Shared handle to a recorder, installable on a `Simulation`.
pub type TraceHandle = Arc<Mutex<TraceRecorder>>;

/// Wrap a recorder into a [`TraceHandle`].
pub fn handle(rec: TraceRecorder) -> TraceHandle {
    Arc::new(Mutex::new(rec))
}

/// Deterministic bounded-memory flight recorder (see module docs).
#[derive(Debug)]
pub struct TraceRecorder {
    cfg: TraceConfig,
    pid_base: u32,
    events: VecDeque<Rec>,
    dropped: u64,
    procs: BTreeMap<u32, String>,
    threads: BTreeMap<(u32, u32), String>,
    /// Gauge sampling cursor (sim-time of the next due sample).
    next_gauge_ns: TimeNs,
    /// Last DTM throttled-chiplet count seen (change detection).
    last_throttled: Option<usize>,
}

impl TraceRecorder {
    pub fn new(cfg: TraceConfig) -> TraceRecorder {
        TraceRecorder {
            cfg,
            pid_base: 0,
            events: VecDeque::new(),
            dropped: 0,
            procs: BTreeMap::new(),
            threads: BTreeMap::new(),
            next_gauge_ns: 0,
            last_throttled: None,
        }
    }

    /// Offset every pid by `base` (merged fleet traces give replica `r`
    /// base `r * PID_STRIDE`).
    pub fn with_pid_base(mut self, base: u32) -> TraceRecorder {
        self.pid_base = base;
        self
    }

    pub fn config(&self) -> &TraceConfig {
        &self.cfg
    }

    /// True when `cat` passes the category filter — check before paying
    /// for argument construction at a hook site.
    pub fn enabled(&self, cat: TraceCategories) -> bool {
        self.cfg.categories.contains(cat)
    }

    /// True when per-request breakdowns should be accumulated.
    pub fn breakdown_enabled(&self) -> bool {
        self.cfg.breakdown
    }

    /// Number of buffered events.
    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Events evicted by the ring-buffer cap.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Clear all buffered state so the recorder can be reused by the
    /// next run with byte-identical output.
    pub fn reset(&mut self) {
        self.events.clear();
        self.dropped = 0;
        self.procs.clear();
        self.threads.clear();
        self.next_gauge_ns = 0;
        self.last_throttled = None;
    }

    /// True when a gauge sample is due at sim-time `now` (and advances
    /// the cursor one [`TraceConfig::gauge_ns`] period past `now`).
    /// Always false with the `gauges` category filtered out.
    pub fn gauge_due(&mut self, now: TimeNs) -> bool {
        if !self.enabled(TraceCategories::GAUGES) || now < self.next_gauge_ns {
            return false;
        }
        self.next_gauge_ns = now + self.cfg.gauge_ns.max(1);
        true
    }

    /// Change detector for the DTM throttled-chiplet count: true when
    /// `n` differs from the previously reported value.
    pub fn throttled_changed(&mut self, n: usize) -> bool {
        if self.last_throttled == Some(n) {
            false
        } else {
            self.last_throttled = Some(n);
            true
        }
    }

    fn push(&mut self, rec: Rec) {
        if self.events.len() >= self.cfg.capacity {
            self.events.pop_front();
            self.dropped += 1;
        }
        self.events.push_back(rec);
    }

    /// Name a process track (idempotent; first name wins).
    pub fn name_process(&mut self, pid: u32, name: &str) {
        let pid = self.pid_base + pid;
        self.procs.entry(pid).or_insert_with(|| name.to_string());
    }

    /// Name a thread track (idempotent; first name wins).
    pub fn name_thread(&mut self, pid: u32, tid: u32, name: &str) {
        let pid = self.pid_base + pid;
        self.threads.entry((pid, tid)).or_insert_with(|| name.to_string());
    }

    /// Record a complete span (`X`).
    pub fn span(
        &mut self,
        cat: TraceCategories,
        pid: u32,
        tid: u32,
        name: impl Into<String>,
        ts: TimeNs,
        dur: TimeNs,
        args: Vec<(&'static str, Value)>,
    ) {
        if !self.enabled(cat) {
            return;
        }
        let pid = self.pid_base + pid;
        self.push(Rec { ts, pid, tid, cat: cat.label(), name: name.into(), ph: Ph::Span { dur }, args });
    }

    /// Record a thread-scoped instant (`i`).
    pub fn instant(
        &mut self,
        cat: TraceCategories,
        pid: u32,
        tid: u32,
        name: impl Into<String>,
        ts: TimeNs,
        args: Vec<(&'static str, Value)>,
    ) {
        if !self.enabled(cat) {
            return;
        }
        let pid = self.pid_base + pid;
        self.push(Rec { ts, pid, tid, cat: cat.label(), name: name.into(), ph: Ph::Instant, args });
    }

    /// Record a counter sample (`C`); `series` are the stacked values.
    pub fn counter(
        &mut self,
        cat: TraceCategories,
        pid: u32,
        name: impl Into<String>,
        ts: TimeNs,
        series: Vec<(&'static str, f64)>,
    ) {
        if !self.enabled(cat) {
            return;
        }
        let pid = self.pid_base + pid;
        let args = series.into_iter().map(|(k, v)| (k, Value::from(v))).collect();
        self.push(Rec { ts, pid, tid: 0, cat: cat.label(), name: name.into(), ph: Ph::Counter, args });
    }

    /// Begin an async lifecycle (`b`) keyed by `id`.
    #[allow(clippy::too_many_arguments)]
    pub fn async_begin(
        &mut self,
        cat: TraceCategories,
        pid: u32,
        tid: u32,
        name: impl Into<String>,
        id: u64,
        ts: TimeNs,
        args: Vec<(&'static str, Value)>,
    ) {
        if !self.enabled(cat) {
            return;
        }
        let pid = self.pid_base + pid;
        self.push(Rec { ts, pid, tid, cat: cat.label(), name: name.into(), ph: Ph::AsyncBegin { id }, args });
    }

    /// Async instant (`n`) inside the lifecycle keyed by `id`.
    #[allow(clippy::too_many_arguments)]
    pub fn async_instant(
        &mut self,
        cat: TraceCategories,
        pid: u32,
        tid: u32,
        name: impl Into<String>,
        id: u64,
        ts: TimeNs,
        args: Vec<(&'static str, Value)>,
    ) {
        if !self.enabled(cat) {
            return;
        }
        let pid = self.pid_base + pid;
        self.push(Rec { ts, pid, tid, cat: cat.label(), name: name.into(), ph: Ph::AsyncInstant { id }, args });
    }

    /// End an async lifecycle (`e`) keyed by `id`.
    #[allow(clippy::too_many_arguments)]
    pub fn async_end(
        &mut self,
        cat: TraceCategories,
        pid: u32,
        tid: u32,
        name: impl Into<String>,
        id: u64,
        ts: TimeNs,
        args: Vec<(&'static str, Value)>,
    ) {
        if !self.enabled(cat) {
            return;
        }
        let pid = self.pid_base + pid;
        self.push(Rec { ts, pid, tid, cat: cat.label(), name: name.into(), ph: Ph::AsyncEnd { id }, args });
    }

    fn rec_to_json(r: &Rec) -> Value {
        let (ph, extra): (&str, Vec<(&'static str, Value)>) = match &r.ph {
            Ph::Span { dur } => ("X", vec![("dur", Value::from(*dur as f64 / 1e3))]),
            Ph::Instant => ("i", vec![("s", Value::from("t"))]),
            Ph::Counter => ("C", vec![]),
            Ph::AsyncBegin { id } => ("b", vec![("id", Value::from(format!("{id:#x}")))]),
            Ph::AsyncInstant { id } => ("n", vec![("id", Value::from(format!("{id:#x}")))]),
            Ph::AsyncEnd { id } => ("e", vec![("id", Value::from(format!("{id:#x}")))]),
        };
        let mut fields: Vec<(&str, Value)> = vec![
            ("name", Value::from(r.name.clone())),
            ("cat", Value::from(r.cat)),
            ("ph", Value::from(ph)),
            ("ts", Value::from(r.ts as f64 / 1e3)),
            ("pid", Value::from(r.pid as u64)),
            ("tid", Value::from(r.tid as u64)),
        ];
        fields.extend(extra);
        fields.push((
            "args",
            Value::obj(r.args.iter().map(|(k, v)| (*k, v.clone())).collect()),
        ));
        Value::obj(fields)
    }

    fn meta_events(&self) -> Vec<Value> {
        let mut out = Vec::new();
        for (pid, name) in &self.procs {
            out.push(Value::obj(vec![
                ("name", Value::from("process_name")),
                ("ph", Value::from("M")),
                ("pid", Value::from(*pid as u64)),
                ("tid", Value::from(0u64)),
                ("args", Value::obj(vec![("name", Value::from(name.clone()))])),
            ]));
        }
        for ((pid, tid), name) in &self.threads {
            out.push(Value::obj(vec![
                ("name", Value::from("thread_name")),
                ("ph", Value::from("M")),
                ("pid", Value::from(*pid as u64)),
                ("tid", Value::from(*tid as u64)),
                ("args", Value::obj(vec![("name", Value::from(name.clone()))])),
            ]));
        }
        out
    }

    /// Export as a Chrome trace-event JSON document.
    pub fn export(&self) -> Value {
        merge_export(std::slice::from_ref(self))
    }

    /// Compact single-line JSON string of [`export`](Self::export).
    pub fn export_string(&self) -> String {
        crate::util::json::to_string(&self.export())
    }

    /// FNV-1a fingerprint of the exported JSON (byte-identical traces
    /// fingerprint identically).
    pub fn fingerprint(&self) -> u64 {
        fnv1a(self.export_string().as_bytes())
    }
}

/// Merge several recorders (e.g. one per fleet replica, each with its
/// own pid base) into one Chrome trace-event document.
pub fn merge_export(recs: &[&TraceRecorder]) -> Value {
    let _prof = crate::prof::scope(crate::prof::Subsystem::TraceExport);
    let mut events: Vec<Value> = Vec::new();
    let mut dropped = 0u64;
    for r in recs {
        events.extend(r.meta_events());
    }
    for r in recs {
        dropped += r.dropped;
        events.extend(r.events.iter().map(TraceRecorder::rec_to_json));
    }
    Value::obj(vec![
        ("traceEvents", Value::Arr(events)),
        ("displayTimeUnit", Value::from("ns")),
        (
            "otherData",
            Value::obj(vec![
                ("generator", Value::from("chipsim flight recorder")),
                ("dropped_events", Value::from(dropped as f64)),
            ]),
        ),
    ])
}

/// FNV-1a 64-bit hash.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

// ---------------------------------------------------------------------------
// Latency breakdown
// ---------------------------------------------------------------------------

/// Where one completed request's end-to-end latency went.  The six
/// components sum **exactly** to `finished - arrival` (see module docs
/// for the attribution rules).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LatencyBreakdown {
    pub dispatch_queue_ns: u64,
    pub mapping_wait_ns: u64,
    pub compute_ns: u64,
    pub dtm_throttle_ns: u64,
    pub noi_serialization_ns: u64,
    pub noi_contention_ns: u64,
}

impl LatencyBreakdown {
    /// Sum of all components == end-to-end latency.
    pub fn total_ns(&self) -> u64 {
        self.dispatch_queue_ns
            + self.mapping_wait_ns
            + self.compute_ns
            + self.dtm_throttle_ns
            + self.noi_serialization_ns
            + self.noi_contention_ns
    }

    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("dispatch_queue_ns", self.dispatch_queue_ns.into()),
            ("mapping_wait_ns", self.mapping_wait_ns.into()),
            ("compute_ns", self.compute_ns.into()),
            ("dtm_throttle_ns", self.dtm_throttle_ns.into()),
            ("noi_serialization_ns", self.noi_serialization_ns.into()),
            ("noi_contention_ns", self.noi_contention_ns.into()),
            ("total_ns", self.total_ns().into()),
        ])
    }
}

/// Per-request accumulator of breakdown evidence, owned by the
/// simulation's in-flight instance state and finalized at completion.
#[derive(Debug, Clone, Default)]
pub struct BreakdownAcc {
    arrival_ns: TimeNs,
    mapped_ns: TimeNs,
    /// Compute spans [start, end).
    compute: Vec<(TimeNs, TimeNs)>,
    /// Sum of (actual - unthrottled) compute latency.
    throttle_ns: u64,
    /// Communication spans [start, end).
    comm: Vec<(TimeNs, TimeNs)>,
    /// Sum of per-flow zero-contention latency estimates.
    ideal_comm_ns: u64,
    /// Open communication windows keyed by (destination layer,
    /// inference): emission time and the zero-contention estimate of the
    /// slowest flow in the batch.  Closed by [`on_comm_done`].
    ///
    /// [`on_comm_done`]: BreakdownAcc::on_comm_done
    pending: HashMap<(usize, u32), (TimeNs, u64)>,
}

impl BreakdownAcc {
    pub fn new(arrival_ns: TimeNs) -> BreakdownAcc {
        BreakdownAcc { arrival_ns, mapped_ns: arrival_ns, ..BreakdownAcc::default() }
    }

    /// The model was mapped at `t` (after zero or more retries).
    pub fn on_mapped(&mut self, t: TimeNs) {
        self.mapped_ns = t.max(self.arrival_ns);
    }

    /// A compute segment ran [start, start+dur); `base_dur` is what it
    /// would have taken unthrottled (DVFS level 0).
    pub fn on_compute(&mut self, start: TimeNs, dur: TimeNs, base_dur: TimeNs) {
        if dur == 0 {
            return;
        }
        self.compute.push((start, start + dur));
        self.throttle_ns += dur.saturating_sub(base_dur);
    }

    /// A flow of this request occupied [start, end) on the fabric;
    /// `ideal_ns` is its zero-contention latency estimate.
    pub fn on_comm(&mut self, start: TimeNs, end: TimeNs, ideal_ns: TimeNs) {
        if end <= start {
            return;
        }
        self.comm.push((start, end));
        self.ideal_comm_ns += ideal_ns.min(end - start);
    }

    /// A batch of flows feeding (`layer`, `inference`) was emitted at
    /// `start`; `ideal_ns` is the zero-contention latency estimate of
    /// one such flow.  Repeated calls for the same key keep the earliest
    /// start and the slowest estimate (the batch completes when its last
    /// flow lands).
    pub fn on_flows(&mut self, layer: usize, inference: u32, start: TimeNs, ideal_ns: u64) {
        let e = self.pending.entry((layer, inference)).or_insert((start, ideal_ns));
        e.0 = e.0.min(start);
        e.1 = e.1.max(ideal_ns);
    }

    /// The last flow feeding (`layer`, `inference`) landed at `end`,
    /// closing the communication window opened by [`on_flows`].
    ///
    /// [`on_flows`]: BreakdownAcc::on_flows
    pub fn on_comm_done(&mut self, layer: usize, inference: u32, end: TimeNs) {
        if let Some((start, ideal)) = self.pending.remove(&(layer, inference)) {
            self.on_comm(start, end, ideal);
        }
    }

    /// Finalize into a [`LatencyBreakdown`] whose components sum exactly
    /// to `finished - arrival`.
    pub fn finish(&self, finished: TimeNs) -> LatencyBreakdown {
        let arrival = self.arrival_ns.min(finished);
        let mapped = self.mapped_ns.clamp(arrival, finished);
        let comp = merge_intervals(self.compute.clone());
        let comm = merge_intervals(self.comm.clone());
        let compute_cov = clipped_len(&comp, mapped, finished);
        let comm_cov = clipped_len_minus(&comm, &comp, mapped, finished);
        let exec = finished - mapped;
        debug_assert!(compute_cov + comm_cov <= exec);
        let mapping_wait = exec - compute_cov - comm_cov;
        let dtm = self.throttle_ns.min(compute_cov);
        let ser = self.ideal_comm_ns.min(comm_cov);
        LatencyBreakdown {
            dispatch_queue_ns: mapped - arrival,
            mapping_wait_ns: mapping_wait,
            compute_ns: compute_cov - dtm,
            dtm_throttle_ns: dtm,
            noi_serialization_ns: ser,
            noi_contention_ns: comm_cov - ser,
        }
    }
}

/// Sort and coalesce intervals into a disjoint, ordered set.
fn merge_intervals(mut v: Vec<(TimeNs, TimeNs)>) -> Vec<(TimeNs, TimeNs)> {
    v.retain(|(s, e)| e > s);
    v.sort_unstable();
    let mut out: Vec<(TimeNs, TimeNs)> = Vec::with_capacity(v.len());
    for (s, e) in v {
        match out.last_mut() {
            Some((_, pe)) if s <= *pe => *pe = (*pe).max(e),
            _ => out.push((s, e)),
        }
    }
    out
}

/// Total length of disjoint ordered intervals clipped to [lo, hi].
fn clipped_len(merged: &[(TimeNs, TimeNs)], lo: TimeNs, hi: TimeNs) -> u64 {
    merged.iter().map(|&(s, e)| e.min(hi).saturating_sub(s.max(lo))).sum()
}

/// Length of `a ∩ [lo, hi] \ b` for disjoint ordered interval sets.
fn clipped_len_minus(
    a: &[(TimeNs, TimeNs)],
    b: &[(TimeNs, TimeNs)],
    lo: TimeNs,
    hi: TimeNs,
) -> u64 {
    let mut total = 0u64;
    for &(s, e) in a {
        let (s, e) = (s.max(lo), e.min(hi));
        if e <= s {
            continue;
        }
        let mut len = e - s;
        for &(bs, be) in b {
            if be <= s {
                continue;
            }
            if bs >= e {
                break;
            }
            len -= be.min(e) - bs.max(s);
        }
        total += len;
    }
    total
}

/// Per-component latency histograms aggregated over completed requests.
#[derive(Debug, Clone)]
pub struct BreakdownStats {
    pub count: u64,
    pub dispatch_queue: LatencyHistogram,
    pub mapping_wait: LatencyHistogram,
    pub compute: LatencyHistogram,
    pub dtm_throttle: LatencyHistogram,
    pub noi_serialization: LatencyHistogram,
    pub noi_contention: LatencyHistogram,
    pub end_to_end: LatencyHistogram,
}

impl Default for BreakdownStats {
    fn default() -> BreakdownStats {
        BreakdownStats::new()
    }
}

impl BreakdownStats {
    pub fn new() -> BreakdownStats {
        BreakdownStats {
            count: 0,
            dispatch_queue: LatencyHistogram::new(),
            mapping_wait: LatencyHistogram::new(),
            compute: LatencyHistogram::new(),
            dtm_throttle: LatencyHistogram::new(),
            noi_serialization: LatencyHistogram::new(),
            noi_contention: LatencyHistogram::new(),
            end_to_end: LatencyHistogram::new(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn record(&mut self, b: &LatencyBreakdown) {
        self.count += 1;
        self.dispatch_queue.record(b.dispatch_queue_ns);
        self.mapping_wait.record(b.mapping_wait_ns);
        self.compute.record(b.compute_ns);
        self.dtm_throttle.record(b.dtm_throttle_ns);
        self.noi_serialization.record(b.noi_serialization_ns);
        self.noi_contention.record(b.noi_contention_ns);
        self.end_to_end.record(b.total_ns());
    }

    pub fn merge(&mut self, other: &BreakdownStats) {
        self.count += other.count;
        self.dispatch_queue.merge(&other.dispatch_queue);
        self.mapping_wait.merge(&other.mapping_wait);
        self.compute.merge(&other.compute);
        self.dtm_throttle.merge(&other.dtm_throttle);
        self.noi_serialization.merge(&other.noi_serialization);
        self.noi_contention.merge(&other.noi_contention);
        self.end_to_end.merge(&other.end_to_end);
    }

    /// (label, histogram) rows in canonical order.
    pub fn rows(&self) -> Vec<(&'static str, &LatencyHistogram)> {
        vec![
            ("dispatch-queue", &self.dispatch_queue),
            ("mapping-wait", &self.mapping_wait),
            ("compute", &self.compute),
            ("dtm-throttle", &self.dtm_throttle),
            ("noi-serialization", &self.noi_serialization),
            ("noi-contention", &self.noi_contention),
            ("end-to-end", &self.end_to_end),
        ]
    }

    /// Paper-style table: per-component mean/p50/p99 and the mean share
    /// of end-to-end latency.
    pub fn table(&self) -> crate::util::benchkit::Table {
        let mut t = crate::util::benchkit::Table::new(
            "latency breakdown (per completed request)",
            &["component", "mean", "p50", "p99", "share"],
        );
        let e2e_mean = self.end_to_end.mean().max(1e-9);
        for (name, h) in self.rows() {
            let share = if name == "end-to-end" {
                "100%".to_string()
            } else {
                format!("{:.1}%", h.mean() / e2e_mean * 100.0)
            };
            t.row(vec![
                name.to_string(),
                crate::util::benchkit::fmt_ns(h.mean()),
                crate::util::benchkit::fmt_ns(h.quantile(0.50) as f64),
                crate::util::benchkit::fmt_ns(h.quantile(0.99) as f64),
                share,
            ]);
        }
        t
    }

    pub fn to_json(&self) -> Value {
        let mut fields: Vec<(&str, Value)> = vec![("count", self.count.into())];
        let rows = self.rows();
        for (name, h) in rows {
            fields.push((
                name,
                Value::obj(vec![
                    ("mean_ns", h.mean().into()),
                    ("p50_ns", h.quantile(0.50).into()),
                    ("p99_ns", h.quantile(0.99).into()),
                ]),
            ));
        }
        Value::obj(fields)
    }

    /// Order-insensitive digest over component quantiles.
    pub fn fingerprint(&self) -> u64 {
        let mut h: u64 = 0xcbf29ce484222325;
        let mut mix = |x: u64| {
            for b in x.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x100000001b3);
            }
        };
        mix(self.count);
        for (_, hist) in self.rows() {
            mix(hist.count());
            mix(hist.quantile(0.50));
            mix(hist.quantile(0.99));
            mix(hist.mean().to_bits());
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn categories_parse_and_filter() {
        let c = TraceCategories::parse("request, noi").unwrap();
        assert!(c.contains(TraceCategories::REQUEST));
        assert!(c.contains(TraceCategories::NOI));
        assert!(!c.contains(TraceCategories::COMPUTE));
        assert_eq!(TraceCategories::parse("all").unwrap(), TraceCategories::all());
        assert!(TraceCategories::parse("bogus").is_err());
    }

    #[test]
    fn ring_buffer_evicts_oldest() {
        let mut r = TraceRecorder::new(TraceConfig::default().capacity(2));
        for i in 0..5u64 {
            r.instant(TraceCategories::REQUEST, PID_REQUEST, 0, "a", i, vec![]);
        }
        assert_eq!(r.len(), 2);
        assert_eq!(r.dropped(), 3);
    }

    #[test]
    fn category_filter_drops_events() {
        let cfg = TraceConfig::default().categories(TraceCategories::REQUEST);
        let mut r = TraceRecorder::new(cfg);
        r.instant(TraceCategories::REQUEST, PID_REQUEST, 0, "keep", 1, vec![]);
        r.span(TraceCategories::COMPUTE, PID_CHIPLET, 0, "drop", 1, 5, vec![]);
        assert_eq!(r.len(), 1);
    }

    #[test]
    fn export_schema_smoke() {
        let mut r = TraceRecorder::new(TraceConfig::default());
        r.name_process(PID_CHIPLET, "board");
        r.name_thread(PID_CHIPLET, 3, "chiplet 3");
        r.span(TraceCategories::COMPUTE, PID_CHIPLET, 3, "L0", 1_000, 2_000, vec![
            ("layer", Value::from(0u64)),
        ]);
        r.async_begin(TraceCategories::REQUEST, PID_REQUEST, 0, "request", 7, 500, vec![]);
        r.async_end(TraceCategories::REQUEST, PID_REQUEST, 0, "request", 7, 4_000, vec![]);
        r.counter(TraceCategories::GAUGES, PID_GAUGE, "queue", 1_000, vec![("depth", 2.0)]);
        let doc = r.export();
        let evs = match doc.get("traceEvents").unwrap() {
            Value::Arr(a) => a,
            _ => panic!("traceEvents must be an array"),
        };
        // 2 metadata + 4 events.
        assert_eq!(evs.len(), 6);
        let span = evs.iter().find(|e| e.get("ph").and_then(|p| p.as_str()) == Some("X")).unwrap();
        assert!((span.get("ts").unwrap().as_f64().unwrap() - 1.0).abs() < 1e-9);
        assert!((span.get("dur").unwrap().as_f64().unwrap() - 2.0).abs() < 1e-9);
        // Export is deterministic for identical recording sequences.
        assert_eq!(r.fingerprint(), r.fingerprint());
    }

    #[test]
    fn reset_restores_byte_identical_reuse() {
        let record = |r: &mut TraceRecorder| {
            r.name_process(PID_NOI, "noi");
            r.span(TraceCategories::NOI, PID_NOI, 1, "xfer", 10, 20, vec![]);
        };
        let mut r = TraceRecorder::new(TraceConfig::default());
        record(&mut r);
        let first = r.export_string();
        r.reset();
        record(&mut r);
        assert_eq!(first, r.export_string());
    }

    #[test]
    fn interval_union_and_subtraction() {
        let m = merge_intervals(vec![(5, 10), (0, 3), (9, 12), (20, 25)]);
        assert_eq!(m, vec![(0, 3), (5, 12), (20, 25)]);
        assert_eq!(clipped_len(&m, 0, 100), 3 + 7 + 5);
        assert_eq!(clipped_len(&m, 6, 21), 6 + 1);
        let b = merge_intervals(vec![(2, 7), (21, 30)]);
        // a \ b inside [0,100]: [0,2) + [7,12) + [20,21) = 2 + 5 + 1.
        assert_eq!(clipped_len_minus(&m, &b, 0, 100), 8);
    }

    #[test]
    fn breakdown_components_partition_latency() {
        let mut acc = BreakdownAcc::new(100);
        acc.on_mapped(150);
        acc.on_compute(150, 50, 40); // 10 ns throttle
        acc.on_comm(180, 260, 30); // overlaps compute for 20 ns
        acc.on_compute(260, 40, 40);
        let b = acc.finish(320);
        assert_eq!(b.total_ns(), 220);
        assert_eq!(b.dispatch_queue_ns, 50);
        assert_eq!(b.dtm_throttle_ns, 10);
        // comm coverage excludes the compute overlap: [200,260) = 60.
        assert_eq!(b.noi_serialization_ns + b.noi_contention_ns, 60);
        assert_eq!(b.noi_serialization_ns, 30);
        // compute coverage [150,200)+[260,300) = 90, minus 10 throttle.
        assert_eq!(b.compute_ns, 80);
        // idle: [300,320) = 20.
        assert_eq!(b.mapping_wait_ns, 20);
    }

    #[test]
    fn breakdown_sum_is_exact_under_degenerate_inputs() {
        // Unmapped-looking acc, zero-length spans, comm past the finish.
        let mut acc = BreakdownAcc::new(1_000);
        acc.on_compute(900, 0, 0);
        acc.on_comm(1_100, 5_000, 10_000);
        let b = acc.finish(2_000);
        assert_eq!(b.total_ns(), 1_000);
    }

    #[test]
    fn breakdown_stats_aggregate_and_merge() {
        let mut a = BreakdownStats::new();
        let mut acc = BreakdownAcc::new(0);
        acc.on_mapped(10);
        acc.on_compute(10, 80, 80);
        a.record(&acc.finish(100));
        let mut b = BreakdownStats::new();
        b.record(&acc.finish(100));
        a.merge(&b);
        assert_eq!(a.count, 2);
        assert_eq!(a.end_to_end.count(), 2);
        let t = a.table().render();
        assert!(t.contains("dispatch-queue"));
        assert!(t.contains("end-to-end"));
        assert_eq!(a.fingerprint(), a.fingerprint());
    }
}
