//! Result reporting: inaccuracy metrics, CSV/JSON writers, results dir.
//!
//! The paper's headline metric is **percent inaccuracy** of a baseline
//! estimate relative to the co-simulated latency:
//!
//!   inaccuracy = (CHIPSIM − baseline) / baseline × 100 %
//!
//! (the decoupled baselines systematically *under*estimate, so this grows
//! past 100 % under heavy pipelining/contention — e.g. the 340 % AlexNet
//! number in Fig. 6).

use std::path::{Path, PathBuf};

use crate::util::json::Value;

/// Percent inaccuracy of `baseline` vs the co-simulated `chipsim` value.
pub fn inaccuracy_pct(chipsim: f64, baseline: f64) -> f64 {
    if baseline <= 0.0 {
        return 0.0;
    }
    (chipsim - baseline) / baseline * 100.0
}

/// Relative percent difference |a-b|/b.
pub fn rel_diff_pct(a: f64, b: f64) -> f64 {
    if b == 0.0 {
        return 0.0;
    }
    (a - b).abs() / b * 100.0
}

/// Resolve (and create) the results output directory:
/// `CHIPSIM_RESULTS` env var or `./results`.
pub fn results_dir() -> PathBuf {
    let dir = std::env::var("CHIPSIM_RESULTS").map(PathBuf::from).unwrap_or_else(|_| {
        PathBuf::from("results")
    });
    let _ = std::fs::create_dir_all(&dir);
    dir
}

/// Write a string artifact into an explicit directory; returns the
/// path.  This is the injectable seam — tests pass a scratch dir here
/// instead of mutating the process-global `CHIPSIM_RESULTS` (which
/// races under the parallel test harness).
pub fn write_result_in(dir: &Path, name: &str, contents: &str) -> anyhow::Result<PathBuf> {
    let path = dir.join(name);
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(&path, contents)?;
    Ok(path)
}

/// Write a string artifact into the results dir; returns the path.
pub fn write_result(name: &str, contents: &str) -> anyhow::Result<PathBuf> {
    write_result_in(&results_dir(), name, contents)
}

/// Write a JSON artifact into an explicit directory.
pub fn write_json_in(dir: &Path, name: &str, v: &Value) -> anyhow::Result<PathBuf> {
    write_result_in(dir, name, &crate::util::json::to_string_pretty(v))
}

/// Write a JSON artifact into the results dir.
pub fn write_json(name: &str, v: &Value) -> anyhow::Result<PathBuf> {
    write_result(name, &crate::util::json::to_string_pretty(v))
}

/// Simple CSV builder.
pub struct Csv {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Csv {
    pub fn new(header: &[&str]) -> Csv {
        Csv { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",");
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    pub fn save(&self, name: &str) -> anyhow::Result<PathBuf> {
        write_result(name, &self.render())
    }
}

/// Format helper: `123456.7` ns -> `"123.5 µs"` style cells come from
/// benchkit; this one renders a percent cell like the paper's tables.
pub fn pct_cell(x: f64) -> String {
    format!("{x:.0}%")
}

/// True if `name` exists inside `dir` (injectable twin of
/// [`result_exists`]).
pub fn result_exists_in(dir: &Path, name: &str) -> bool {
    dir.join(name).exists()
}

/// True if `path` exists inside the results dir (idempotence checks).
pub fn result_exists(name: &str) -> bool {
    result_exists_in(&results_dir(), name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inaccuracy_definition() {
        // Baseline underestimates 4.4x co-sim => 340%.
        assert!((inaccuracy_pct(4.4, 1.0) - 340.0).abs() < 1e-9);
        assert_eq!(inaccuracy_pct(1.0, 0.0), 0.0);
    }

    #[test]
    fn csv_escapes_commas() {
        let mut c = Csv::new(&["a", "b"]);
        c.row(vec!["x,y".into(), "plain".into()]);
        let s = c.render();
        assert!(s.contains("\"x,y\""));
    }

    #[test]
    fn write_and_check_result() {
        // Injected directory, not the process-global CHIPSIM_RESULTS:
        // mutating the environment races with concurrently running tests.
        let dir = std::env::temp_dir().join("chipsim-test-results");
        let p = write_result_in(&dir, "unit/test.txt", "hello").unwrap();
        assert!(p.exists());
        assert!(result_exists_in(&dir, "unit/test.txt"));
        assert!(!result_exists_in(&dir, "unit/absent.txt"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
