//! # CHIPSIM — co-simulation framework for DNNs on chiplet-based systems
//!
//! Reproduction of Pfromm et al., *"CHIPSIM: A Co-Simulation Framework for
//! Deep Learning on Chiplet-Based Systems"* (IEEE OJSSCS 2025).
//!
//! CHIPSIM concurrently models **computation** (per-chiplet, event-based)
//! and **communication** (cycle-level network-on-interposer) under one
//! global timeline, capturing network contention and DNN layer pipelining
//! that decoupled simulators miss.  It profiles per-chiplet power at
//! microsecond granularity and feeds a multi-fidelity RC thermal model.
//!
//! ## Architecture (three layers, AOT via PJRT)
//!
//! * **L3 (this crate)** — the [`sim::Simulation`] co-simulation loop, the
//!   NoI simulator, pluggable mappers, compute backends, power tracking,
//!   the sustained-traffic serving engine ([`serving`]), the fleet-scale
//!   serving layer ([`fleet`]), baselines, the scenario registry, CLI.
//! * **L2/L1 (python/compile, build-time only)** — JAX graphs + Pallas
//!   kernels for the thermal solver and the batched IMC estimator, lowered
//!   once to HLO text under `artifacts/` by `make artifacts`.
//! * **runtime** — loads those artifacts through the PJRT CPU client
//!   (`xla` crate) from the Rust hot path.  Python never runs at request
//!   time.
//!
//! ## Quickstart
//!
//! Every co-simulation is assembled by the [`sim::Simulation`] builder;
//! each part (mapper, network fidelity, compute backend, thermal
//! coupling, observers) defaults sensibly and can be swapped
//! independently:
//!
//! ```no_run
//! use chipsim::prelude::*;
//!
//! let hw = HardwareConfig::homogeneous_mesh(4, 4);
//! let params = SimParams { pipelined: true, ..SimParams::default() };
//! let report = Simulation::builder()
//!     .hardware(hw)
//!     .params(params)
//!     .build()
//!     .expect("valid configuration")
//!     .run(WorkloadConfig::cnn_stream(8, 10, 0xC0FFEE))
//!     .expect("co-simulation");
//! println!("{}", report.summary());
//! ```
//!
//! Or run a named preset from the scenario registry — and whole batches
//! of them, in parallel, with deterministic seeds:
//!
//! ```no_run
//! use chipsim::prelude::*;
//!
//! let reg = Registry::builtin();
//! let report = reg.get("mesh-6x6-quickstart").unwrap().run(0xBEEF).unwrap();
//! println!("{}", report.summary());
//!
//! let outcomes = SweepRunner::new()
//!     .run(&reg, &["mesh-10x10-cnn", "hetero-mesh", "floret", "ccd-star"])
//!     .unwrap();
//! ```
//!
//! Closed-loop dynamic thermal management lives in [`dtm`]: build a
//! simulation with `ThermalSpec::InLoop { window_ns, governor }` and the
//! run steps the RC network in-loop, polls per-chiplet sensors, and lets
//! a DVFS governor scale the latency and dynamic power of subsequently
//! issued compute.
//!
//! See `examples/` for complete drivers and `rust/benches/` for the
//! regeneration harness of every table and figure in the paper.

pub mod util;
pub mod config;
pub mod fault;
pub mod workload;
pub mod mapping;
pub mod noc;
pub mod par;
pub mod compute;
pub mod sim;
pub mod trace;
pub mod prof;
pub mod instrument;
pub mod scenario;
pub mod serving;
pub mod fleet;
pub mod power;
pub mod thermal;
pub mod dtm;
pub mod baselines;
pub mod experiments;
pub mod hwemu;
pub mod metrics;
pub mod runtime;

/// Convenience re-exports for the common entry points.
pub mod prelude {
    pub use crate::config::{
        ChipletClass, HardwareConfig, LinkParams, NocFidelity, SimParams, TopologyKind,
        WorkloadConfig,
    };
    pub use crate::mapping::{MapContext, Mapper, NearestNeighbor, PlacementPolicy, TenantDemand};
    pub use crate::scenario::{Registry, Scenario, SweepOutcome, SweepRunner};
    pub use crate::serving::{
        ArrivalSpec, InterferenceMatrix, LatencyHistogram, LoadSweep, MixReport, ServingStats,
        SteadyState, StopReason, TenantSpec, TrafficReport, TrafficSpec, WorkloadMix,
    };
    pub use crate::dtm::{
        DtmReport, DvfsState, DvfsTable, Governor, GovernorPolicy, GovernorSpec, SensorSpec,
    };
    pub use crate::fault::{FaultKind, FaultPlan, FaultReport, RetryPolicy};
    pub use crate::fleet::{
        Autoscaler, Fleet, FleetReport, FleetSpec, ReplicaSnapshot, RoutingPolicy, ScaleEvent,
    };
    pub use crate::instrument::{Instrumentation, RunOptions};
    pub use crate::par::{ExecSpec, Partitioner};
    pub use crate::sim::{
        SimObserver, SimReport, Simulation, SimulationBuilder, ThermalSpec,
    };
    pub use crate::trace::{
        BreakdownStats, LatencyBreakdown, TraceCategories, TraceConfig, TraceRecorder,
    };
    pub use crate::prof::ProfileReport;
    pub use crate::workload::{ModelKind, NeuralModel};
}

/// Simulation time in nanoseconds (the coherent global timeline).
pub type TimeNs = u64;

/// Power-bin width: the paper tracks power at 1 microsecond granularity.
pub const POWER_BIN_NS: TimeNs = 1_000;
