//! # CHIPSIM — co-simulation framework for DNNs on chiplet-based systems
//!
//! Reproduction of Pfromm et al., *"CHIPSIM: A Co-Simulation Framework for
//! Deep Learning on Chiplet-Based Systems"* (IEEE OJSSCS 2025).
//!
//! CHIPSIM concurrently models **computation** (per-chiplet, event-based)
//! and **communication** (cycle-level network-on-interposer) under one
//! global timeline, capturing network contention and DNN layer pipelining
//! that decoupled simulators miss.  It profiles per-chiplet power at
//! microsecond granularity and feeds a multi-fidelity RC thermal model.
//!
//! ## Architecture (three layers, AOT via PJRT)
//!
//! * **L3 (this crate)** — the Global Manager co-simulation loop, the NoI
//!   simulator, mapper, compute backends, power tracking, baselines, CLI.
//! * **L2/L1 (python/compile, build-time only)** — JAX graphs + Pallas
//!   kernels for the thermal solver and the batched IMC estimator, lowered
//!   once to HLO text under `artifacts/` by `make artifacts`.
//! * **runtime** — loads those artifacts through the PJRT CPU client
//!   (`xla` crate) from the Rust hot path.  Python never runs at request
//!   time.
//!
//! ## Quickstart
//!
//! ```no_run
//! use chipsim::prelude::*;
//!
//! let hw = HardwareConfig::homogeneous_mesh(4, 4);
//! let wl = WorkloadConfig::cnn_stream(8, 3, 0xC0FFEE);
//! let params = SimParams { pipelined: true, ..SimParams::default() };
//! let report = chipsim::sim::GlobalManager::new(hw, params)
//!     .run(wl)
//!     .expect("simulation");
//! println!("{}", report.summary());
//! ```
//!
//! See `examples/` for complete drivers and `rust/benches/` for the
//! regeneration harness of every table and figure in the paper.

pub mod util;
pub mod config;
pub mod workload;
pub mod mapping;
pub mod noc;
pub mod compute;
pub mod sim;
pub mod power;
pub mod thermal;
pub mod baselines;
pub mod experiments;
pub mod hwemu;
pub mod metrics;
pub mod runtime;

/// Convenience re-exports for the common entry points.
pub mod prelude {
    pub use crate::config::{
        ChipletClass, HardwareConfig, LinkParams, SimParams, TopologyKind, WorkloadConfig,
    };
    pub use crate::sim::{GlobalManager, SimReport};
    pub use crate::workload::{ModelKind, NeuralModel};
}

/// Simulation time in nanoseconds (the coherent global timeline).
pub type TimeNs = u64;

/// Power-bin width: the paper tracks power at 1 microsecond granularity.
pub const POWER_BIN_NS: TimeNs = 1_000;
