//! Minimal `log` facade backend (env_logger replacement).
//!
//! Level comes from `CHIPSIM_LOG` (error|warn|info|debug|trace), default
//! `info`.  Install once with [`init`]; repeated calls are no-ops.

use log::{Level, LevelFilter, Metadata, Record};

struct StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if self.enabled(record.metadata()) {
            let tag = match record.level() {
                Level::Error => "E",
                Level::Warn => "W",
                Level::Info => "I",
                Level::Debug => "D",
                Level::Trace => "T",
            };
            eprintln!("[{tag} {}] {}", record.target(), record.args());
        }
    }

    fn flush(&self) {}
}

static LOGGER: StderrLogger = StderrLogger;

/// Install the stderr logger (idempotent).
pub fn init() {
    let level = match std::env::var("CHIPSIM_LOG").as_deref() {
        Ok("error") => LevelFilter::Error,
        Ok("warn") => LevelFilter::Warn,
        Ok("debug") => LevelFilter::Debug,
        Ok("trace") => LevelFilter::Trace,
        _ => LevelFilter::Info,
    };
    // set_logger errors if already installed; that's fine.
    let _ = log::set_logger(&LOGGER);
    log::set_max_level(level);
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke test");
    }
}
