//! Minimal `log` facade backend (env_logger replacement).
//!
//! Level comes from `CHIPSIM_LOG` (off|error|warn|info|debug|trace),
//! default `info`.  Install once with [`init`]; repeated calls are
//! no-ops.
//!
//! When a co-simulation run is advancing it publishes its monotonic sim
//! clock via [`set_sim_now`] (thread-local, so parallel fleet replicas
//! do not interleave), and every log line emitted from inside the run
//! carries a `@<ns>ns` prefix.  [`crate::warn_once!`] deduplicates
//! repeated warnings per run — [`reset_warn_once`] is called by
//! `begin_run` so each run warns at most once per distinct message.

use std::cell::Cell;
use std::collections::HashSet;
use std::sync::Mutex;

use log::{Level, LevelFilter, Metadata, Record};

use crate::TimeNs;

thread_local! {
    static SIM_NOW: Cell<Option<TimeNs>> = const { Cell::new(None) };
}

/// Publish the current sim time for log-line prefixes on this thread.
pub fn set_sim_now(now: TimeNs) {
    SIM_NOW.with(|c| c.set(Some(now)));
}

/// Clear the sim-time prefix (run paused or finished).
pub fn clear_sim_now() {
    SIM_NOW.with(|c| c.set(None));
}

struct StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if self.enabled(record.metadata()) {
            let tag = match record.level() {
                Level::Error => "E",
                Level::Warn => "W",
                Level::Info => "I",
                Level::Debug => "D",
                Level::Trace => "T",
            };
            match SIM_NOW.with(|c| c.get()) {
                Some(now) => {
                    eprintln!("[{tag} {} @{now}ns] {}", record.target(), record.args())
                }
                None => eprintln!("[{tag} {}] {}", record.target(), record.args()),
            }
        }
    }

    fn flush(&self) {}
}

static LOGGER: StderrLogger = StderrLogger;

/// Install the stderr logger (idempotent).
pub fn init() {
    let level = match std::env::var("CHIPSIM_LOG").as_deref() {
        Ok("off") | Ok("none") => LevelFilter::Off,
        Ok("error") => LevelFilter::Error,
        Ok("warn") => LevelFilter::Warn,
        Ok("debug") => LevelFilter::Debug,
        Ok("trace") => LevelFilter::Trace,
        _ => LevelFilter::Info,
    };
    // set_logger errors if already installed; that's fine.
    let _ = log::set_logger(&LOGGER);
    log::set_max_level(level);
}

static WARNED: Mutex<Option<HashSet<String>>> = Mutex::new(None);

/// True the first time `msg` is seen since the last
/// [`reset_warn_once`] — the predicate behind [`crate::warn_once!`].
pub fn first_occurrence(msg: &str) -> bool {
    let mut guard = WARNED.lock().unwrap_or_else(|p| p.into_inner());
    guard.get_or_insert_with(HashSet::new).insert(msg.to_string())
}

/// Forget which warnings were already emitted (called at run start so
/// deduplication is per-run, not per-process).
pub fn reset_warn_once() {
    let mut guard = WARNED.lock().unwrap_or_else(|p| p.into_inner());
    *guard = None;
}

/// `log::warn!` that fires at most once per distinct formatted message
/// per run (see [`reset_warn_once`]).  Repeated per-event warnings —
/// capacity drops, solver fallbacks — flood stderr on long traces;
/// this keeps the first occurrence and counts on the trace/report for
/// the rest.
#[macro_export]
macro_rules! warn_once {
    ($($arg:tt)*) => {{
        let __msg = format!($($arg)*);
        if $crate::util::logging::first_occurrence(&__msg) {
            log::warn!("{}", __msg);
        }
    }};
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke test");
    }

    #[test]
    fn warn_once_deduplicates_until_reset() {
        super::reset_warn_once();
        assert!(super::first_occurrence("msg-a"));
        assert!(!super::first_occurrence("msg-a"));
        assert!(super::first_occurrence("msg-b"));
        super::reset_warn_once();
        assert!(super::first_occurrence("msg-a"));
    }

    #[test]
    fn sim_now_prefix_toggles() {
        super::init();
        super::set_sim_now(1234);
        log::info!("with prefix");
        super::clear_sim_now();
        log::info!("without prefix");
    }
}
