//! Tiny CLI argument parser (clap replacement).
//!
//! Supports `--key value`, `--key=value`, boolean flags, positionals, and
//! auto-generated `--help` text from registered options.

use std::collections::BTreeMap;

/// A parsed command line: positionals plus `--key value` options.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positionals: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    /// `known_flags` lists option names that take NO value.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I, known_flags: &[&str]) -> Args {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if known_flags.contains(&body) {
                    out.flags.push(body.to_string());
                } else if iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = iter.next().unwrap();
                    out.options.insert(body.to_string(), v);
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positionals.push(a);
            }
        }
        out
    }

    /// Parse from the process environment.
    pub fn from_env(known_flags: &[&str]) -> Args {
        Args::parse(std::env::args().skip(1), known_flags)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> anyhow::Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|e| anyhow::anyhow!("--{name} expects an integer, got '{s}': {e}")),
        }
    }

    pub fn get_usize(&self, name: &str, default: usize) -> anyhow::Result<usize> {
        Ok(self.get_u64(name, default as u64)? as usize)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|e| anyhow::anyhow!("--{name} expects a number, got '{s}': {e}")),
        }
    }

    /// Comma-separated list of integers, e.g. `--inferences 1,3,5,10,20`.
    pub fn get_u64_list(&self, name: &str, default: &[u64]) -> anyhow::Result<Vec<u64>> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(s) => s
                .split(',')
                .map(|t| {
                    t.trim()
                        .parse()
                        .map_err(|e| anyhow::anyhow!("--{name} item '{t}': {e}"))
                })
                .collect(),
        }
    }
}

/// Declarative help-text builder used by the launcher.
pub struct HelpText {
    pub name: &'static str,
    pub about: &'static str,
    pub usage: &'static str,
    pub entries: Vec<(&'static str, &'static str)>,
}

impl HelpText {
    pub fn render(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {}\n\nOPTIONS:\n", self.name, self.about, self.usage);
        for (flag, desc) in &self.entries {
            s.push_str(&format!("  {flag:<32} {desc}\n"));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str], flags: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string()), flags)
    }

    #[test]
    fn positionals_and_options() {
        let a = parse(&["run", "--seed", "42", "--topo=floret", "extra"], &[]);
        assert_eq!(a.positionals, vec!["run", "extra"]);
        assert_eq!(a.get("seed"), Some("42"));
        assert_eq!(a.get("topo"), Some("floret"));
    }

    #[test]
    fn flags_vs_valued() {
        let a = parse(&["--verbose", "--n", "5"], &["verbose"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.get_u64("n", 0).unwrap(), 5);
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = parse(&["--pipelined"], &[]);
        assert!(a.flag("pipelined"));
    }

    #[test]
    fn typed_getters_and_defaults() {
        let a = parse(&["--x", "2.5"], &[]);
        assert_eq!(a.get_f64("x", 0.0).unwrap(), 2.5);
        assert_eq!(a.get_u64("missing", 7).unwrap(), 7);
        assert!(a.get_u64("x", 0).is_err());
    }

    #[test]
    fn u64_list() {
        let a = parse(&["--inf", "1,3,5"], &[]);
        assert_eq!(a.get_u64_list("inf", &[]).unwrap(), vec![1, 3, 5]);
        assert_eq!(a.get_u64_list("other", &[10]).unwrap(), vec![10]);
    }
}
