//! Property-based testing driver (proptest replacement).
//!
//! `check(name, cases, |rng| ...)` runs the closure `cases` times with
//! independent seeded RNG streams; a failure panics with the offending
//! seed so the case can be replayed exactly with `check_seed`.

use crate::util::rng::Rng;

/// Run `prop` for `cases` random cases.  `prop` returns `Err(msg)` (or
/// panics) to signal a violated property.
pub fn check<F>(name: &str, cases: usize, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    // Base seed is fixed for reproducibility; override via env for fuzzing.
    let base: u64 = std::env::var("CHIPSIM_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5EED_CAFE);
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed on case {case} (replay: check_seed(\"{name}\", {seed:#x}, ..)): {msg}"
            );
        }
    }
}

/// Replay a single failing case by seed.
pub fn check_seed<F>(name: &str, seed: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    if let Err(msg) = prop(&mut rng) {
        panic!("property '{name}' failed for seed {seed:#x}: {msg}");
    }
}

/// Assertion helper producing `Result<(), String>` for use inside props.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($arg:tt)+) => {
        if !$cond {
            return Err(format!($($arg)+));
        }
    };
    ($cond:expr) => {
        if !$cond {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add-commutes", 50, |rng| {
            let a = rng.below(1000) as i64;
            let b = rng.below(1000) as i64;
            prop_assert!(a + b == b + a);
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn failing_property_reports_seed() {
        check("always-fails", 3, |_rng| Err("nope".to_string()));
    }
}
