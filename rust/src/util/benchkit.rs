//! Benchmark harness (criterion replacement) used by `rust/benches/*`.
//!
//! Two roles:
//!  1. micro/hot-path timing: [`bench`] runs a closure with warmup and
//!     reports mean / p50 / p95 / throughput;
//!  2. experiment tables: [`Table`] prints the paper-style rows that each
//!     bench target regenerates, and can dump them as JSON for
//!     EXPERIMENTS.md bookkeeping.
//!
//! Every [`bench`] call writes its result as `BENCH_<case>.json` into
//! [`bench_json_dir`] — the repo root by default (committed baselines
//! form the perf trajectory), or the directory named by the
//! `CHIPSIM_BENCH_JSON` environment variable (CI writes fresh results to
//! a scratch dir there and compares them against the committed baselines
//! with `python/bench_check.py`).

use std::time::Instant;

/// Result of a timed benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
    /// Derived throughput metrics (e.g. `flit_hops_per_s`) carried into
    /// the JSON artifact for regression checks.
    pub metrics: Vec<(String, f64)>,
}

impl BenchResult {
    /// Filesystem-safe case name: `BENCH_<slug>.json`.
    pub fn case_slug(&self) -> String {
        let mut slug: String = self
            .name
            .chars()
            .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
            .collect();
        while slug.contains("__") {
            slug = slug.replace("__", "_");
        }
        slug.trim_matches('_').to_string()
    }

    /// Attach a derived metric (returns self for chaining); re-save with
    /// [`save_json`](Self::save_json) to persist it into the artifact.
    pub fn with_metric(mut self, name: &str, value: f64) -> Self {
        self.metrics.push((name.to_string(), value));
        self
    }

    /// Machine-readable form of one timed case.
    pub fn to_json(&self) -> crate::util::json::Value {
        use crate::util::json::Value;
        Value::obj(vec![
            ("name", Value::from(self.name.clone())),
            ("iters", self.iters.into()),
            ("mean_ns", self.mean_ns.into()),
            ("p50_ns", self.p50_ns.into()),
            ("p95_ns", self.p95_ns.into()),
            ("min_ns", self.min_ns.into()),
            (
                "metrics",
                Value::obj(
                    self.metrics
                        .iter()
                        .map(|(k, v)| (k.as_str(), Value::from(*v)))
                        .collect(),
                ),
            ),
        ])
    }

    /// Write `BENCH_<case>.json` into `dir` (created if missing).
    pub fn save_json(&self, dir: &str) -> anyhow::Result<std::path::PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = std::path::Path::new(dir).join(format!("BENCH_{}.json", self.case_slug()));
        std::fs::write(&path, crate::util::json::to_string_pretty(&self.to_json()))?;
        Ok(path)
    }

    pub fn print(&self) {
        println!(
            "bench {:<40} iters={:<6} mean={:>12} p50={:>12} p95={:>12} min={:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p95_ns),
            fmt_ns(self.min_ns),
        );
    }
}

/// Human-readable nanoseconds.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Directory `BENCH_<case>.json` artifacts are written to: the value of
/// `CHIPSIM_BENCH_JSON` when set and non-empty, otherwise the current
/// directory (`cargo bench` runs from the workspace root, so results land
/// next to the committed baselines and the perf trajectory tracks in git).
pub fn bench_json_dir() -> String {
    match std::env::var("CHIPSIM_BENCH_JSON") {
        Ok(dir) if !dir.is_empty() => dir,
        _ => ".".to_string(),
    }
}

/// Time `f` for at least `min_iters` iterations and `min_time_ms`
/// milliseconds after one warmup call, writing the JSON artifact into
/// an explicitly injected directory (`None` skips the write).  Tests
/// use this seam directly instead of mutating the process-global
/// `CHIPSIM_BENCH_JSON`, which races under the parallel test harness.
pub fn bench_into<F: FnMut()>(
    dir: Option<&str>,
    name: &str,
    min_iters: usize,
    min_time_ms: u64,
    mut f: F,
) -> BenchResult {
    f(); // warmup
    // Attribute the timed window by subsystem (no-op unless the bench
    // harness enabled self-profiling; skipped under `cargo test`, where
    // the process-global profiler may belong to another test).
    let profiling = !cfg!(test) && crate::prof::enabled();
    if profiling {
        crate::prof::reset();
    }
    let mut samples: Vec<f64> = Vec::new();
    let start = Instant::now();
    while samples.len() < min_iters || start.elapsed().as_millis() < min_time_ms as u128 {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
        if samples.len() > 10_000 {
            break;
        }
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let pct = |p: f64| samples[((samples.len() - 1) as f64 * p) as usize];
    let mut result = BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        mean_ns: mean,
        p50_ns: pct(0.5),
        p95_ns: pct(0.95),
        min_ns: samples[0],
        metrics: Vec::new(),
    };
    if profiling {
        if let Some(p) = crate::prof::snapshot(start.elapsed().as_nanos() as u64) {
            // Per-subsystem wall-clock shares ride in `metrics`, so
            // bench_check.py's delta table makes regressions
            // attributable ("flit_engine share 40% -> 70%"), not just
            // detectable.  Sub-0.1% shares are noise; drop them to keep
            // baselines stable.
            for s in &p.subsystems {
                if s.share >= 0.001 {
                    result.metrics.push((format!("share_{}", s.name), s.share));
                }
            }
        }
    }
    if let Some(dir) = dir {
        if let Err(e) = result.save_json(dir) {
            eprintln!("benchkit: could not write BENCH json into {dir}: {e:#}");
        }
    }
    result
}

/// Time `f` and write `BENCH_<case>.json` into [`bench_json_dir`].
/// Returns stats over per-iter times.
pub fn bench<F: FnMut()>(name: &str, min_iters: usize, min_time_ms: u64, f: F) -> BenchResult {
    // Unit tests exercise the stats path without littering artifacts.
    let dir = if cfg!(test) { None } else { Some(bench_json_dir()) };
    bench_into(dir.as_deref(), name, min_iters, min_time_ms, f)
}

/// A paper-style results table.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Render with per-column width alignment.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let sep: String = widths.iter().map(|w| "-".repeat(w + 2)).collect::<Vec<_>>().join("+");
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(c, s)| format!(" {:<w$} ", s, w = widths[c]))
                .collect::<Vec<_>>()
                .join("|")
        };
        let mut out = format!("== {} ==\n", self.title);
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }

    /// JSON form for machine-readable experiment logs.
    pub fn to_json(&self) -> crate::util::json::Value {
        use crate::util::json::Value;
        Value::obj(vec![
            ("title", Value::from(self.title.clone())),
            (
                "headers",
                Value::Arr(self.headers.iter().map(|h| Value::from(h.clone())).collect()),
            ),
            (
                "rows",
                Value::Arr(
                    self.rows
                        .iter()
                        .map(|r| {
                            Value::Arr(r.iter().map(|c| Value::from(c.clone())).collect())
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Format a ratio as a percent string like the paper's tables.
pub fn pct(x: f64) -> String {
    format!("{:.0}%", x * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_ordered_stats() {
        let r = bench("noop-ish", 16, 1, || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(r.iters >= 16);
        assert!(r.min_ns <= r.p50_ns && r.p50_ns <= r.p95_ns);
    }

    #[test]
    fn bench_into_writes_injected_dir() {
        let dir = std::env::temp_dir().join("chipsim-benchkit-into");
        let r = bench_into(dir.to_str(), "injected case", 4, 1, || {
            std::hint::black_box((0..10).sum::<u64>());
        });
        let path = dir.join(format!("BENCH_{}.json", r.case_slug()));
        assert!(path.exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn table_render_aligns() {
        let mut t = Table::new("demo", &["model", "err"]);
        t.row(vec!["ResNet18".into(), "74%".into()]);
        t.row(vec!["AlexNet".into(), "33%".into()]);
        let s = t.render();
        assert!(s.contains("ResNet18"));
        assert!(s.lines().count() >= 5);
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(512.0), "512 ns");
        assert!(fmt_ns(2_500.0).contains("µs"));
        assert!(fmt_ns(2.5e6).contains("ms"));
        assert!(fmt_ns(2.5e9).contains(" s"));
    }

    #[test]
    #[should_panic]
    fn row_arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn bench_json_artifact_round_trips() {
        let r = BenchResult {
            name: "noc/packet: 200 flows x 64KB on 10x10 mesh".into(),
            iters: 12,
            mean_ns: 1234.5,
            p50_ns: 1200.0,
            p95_ns: 1500.0,
            min_ns: 1100.0,
            metrics: Vec::new(),
        }
        .with_metric("flit_hops_per_s", 2.5e7);
        assert_eq!(r.case_slug(), "noc_packet_200_flows_x_64KB_on_10x10_mesh");
        let dir = std::env::temp_dir().join("chipsim-benchkit-test");
        let path = r.save_json(dir.to_str().unwrap()).unwrap();
        assert!(path.file_name().unwrap().to_str().unwrap().starts_with("BENCH_"));
        let parsed =
            crate::util::json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(parsed.get("iters").unwrap().as_usize().unwrap(), 12);
        assert!((parsed.get("mean_ns").unwrap().as_f64().unwrap() - 1234.5).abs() < 1e-9);
        let m = parsed.get("metrics").unwrap();
        assert!((m.get("flit_hops_per_s").unwrap().as_f64().unwrap() - 2.5e7).abs() < 1.0);
        let _ = std::fs::remove_file(path);
    }
}
