//! Deterministic, seedable PRNG (xoshiro256++ seeded via SplitMix64).
//!
//! Replaces the `rand` crate in this offline build.  Every stochastic
//! decision in the simulator (workload sampling, arrival jitter, property
//! tests) flows through [`Rng`], so a `(seed)` pair fully reproduces a run.

/// SplitMix64: used to expand a single u64 seed into xoshiro state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256++ generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform u64 in [0, bound) without modulo bias (Lemire reduction).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [0, bound).
    #[inline]
    pub fn below_usize(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo + 1)
    }

    /// Bernoulli trial with probability p.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a uniformly random element of a slice.
    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below_usize(items.len())]
    }

    /// Fisher–Yates shuffle in place.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below_usize(i + 1);
            items.swap(i, j);
        }
    }

    /// Fork an independent stream (e.g. one per subsystem) from this one.
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn below_covers_full_range_and_bounds() {
        let mut r = Rng::new(11);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..10_000 {
            let v = r.range_u64(5, 8);
            assert!((5..=8).contains(&v));
            seen_lo |= v == 5;
            seen_hi |= v == 8;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
