//! In-tree substrates replacing unavailable third-party crates.
//!
//! The build image is offline with a minimal vendored registry (see
//! DESIGN.md §3), so the usual ecosystem crates are implemented here as
//! small, well-tested modules:
//!
//! | module     | replaces        | purpose                                |
//! |------------|-----------------|----------------------------------------|
//! | [`json`]   | serde_json      | config + manifest parsing, trace export|
//! | [`rng`]    | rand            | deterministic seedable PRNG            |
//! | [`cli`]    | clap            | argument parsing for the launcher      |
//! | [`benchkit`]| criterion      | bench harness with stats               |
//! | [`propkit`]| proptest        | property-based testing driver          |
//! | [`linalg`] | nalgebra        | dense LU/inverse for thermal precompute|
//! | [`logging`]| env_logger      | `log` facade backend                   |
//! | [`pool`]   | rayon           | scoped panic-catching worker pool      |

pub mod benchkit;
pub mod cli;
pub mod json;
pub mod linalg;
pub mod logging;
pub mod pool;
pub mod propkit;
pub mod rng;
