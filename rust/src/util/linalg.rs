//! Dense linear algebra for the thermal precompute path.
//!
//! The implicit-Euler thermal step needs A = (I + dt C^-1 G)^-1 once per
//! physical configuration; this module supplies the LU factorization,
//! inverse, solve, and matvec used by `thermal::` and by tests that
//! cross-check the PJRT solver.  Row-major `f64` storage.

/// Row-major dense matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub n_rows: usize,
    pub n_cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(n_rows: usize, n_cols: usize) -> Mat {
        Mat { n_rows, n_cols, data: vec![0.0; n_rows * n_cols] }
    }

    pub fn identity(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[&[f64]]) -> Mat {
        let n_rows = rows.len();
        let n_cols = rows[0].len();
        let mut data = Vec::with_capacity(n_rows * n_cols);
        for r in rows {
            assert_eq!(r.len(), n_cols);
            data.extend_from_slice(r);
        }
        Mat { n_rows, n_cols, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.n_cols..(i + 1) * self.n_cols]
    }

    /// y = self @ x
    ///
    /// Four independent accumulators let LLVM vectorize the f64 reduction
    /// without relaxing FP semantics per accumulator chain (strict f64
    /// addition is order-dependent, so a single-accumulator loop cannot be
    /// auto-vectorized) — ~3× on the thermal hot path (EXPERIMENTS §Perf).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.n_cols);
        let mut y = vec![0.0; self.n_rows];
        for i in 0..self.n_rows {
            let row = self.row(i);
            let mut acc = [0.0f64; 4];
            let chunks = self.n_cols / 4 * 4;
            let mut j = 0;
            while j < chunks {
                acc[0] += row[j] * x[j];
                acc[1] += row[j + 1] * x[j + 1];
                acc[2] += row[j + 2] * x[j + 2];
                acc[3] += row[j + 3] * x[j + 3];
                j += 4;
            }
            let mut tail = 0.0;
            while j < self.n_cols {
                tail += row[j] * x[j];
                j += 1;
            }
            y[i] = (acc[0] + acc[1]) + (acc[2] + acc[3]) + tail;
        }
        y
    }

    /// C = self @ other
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.n_cols, other.n_rows);
        let mut out = Mat::zeros(self.n_rows, other.n_cols);
        for i in 0..self.n_rows {
            for k in 0..self.n_cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                let orow = other.row(k);
                let dst =
                    &mut out.data[i * other.n_cols..(i + 1) * other.n_cols];
                for j in 0..other.n_cols {
                    dst[j] += a * orow[j];
                }
            }
        }
        out
    }

    /// Scale row i by s.
    pub fn scale_row(&mut self, i: usize, s: f64) {
        for v in &mut self.data[i * self.n_cols..(i + 1) * self.n_cols] {
            *v *= s;
        }
    }

    /// Frobenius-norm distance to another matrix (test helper).
    pub fn dist(&self, other: &Mat) -> f64 {
        assert_eq!(self.data.len(), other.data.len());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt()
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.n_cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.n_cols + j]
    }
}

/// LU factorization with partial pivoting: PA = LU stored in-place.
pub struct Lu {
    lu: Mat,
    piv: Vec<usize>,
}

impl Lu {
    /// Factorize a square matrix. Errors on (numerical) singularity.
    pub fn factor(a: &Mat) -> anyhow::Result<Lu> {
        assert_eq!(a.n_rows, a.n_cols, "LU needs a square matrix");
        let n = a.n_rows;
        let mut lu = a.clone();
        let mut piv: Vec<usize> = (0..n).collect();
        for k in 0..n {
            // Pivot: largest |value| in column k at/below the diagonal.
            let mut p = k;
            let mut pmax = lu[(k, k)].abs();
            for i in k + 1..n {
                let v = lu[(i, k)].abs();
                if v > pmax {
                    pmax = v;
                    p = i;
                }
            }
            if pmax < 1e-300 {
                anyhow::bail!("singular matrix at pivot {k}");
            }
            if p != k {
                for j in 0..n {
                    lu.data.swap(k * n + j, p * n + j);
                }
                piv.swap(k, p);
            }
            let pivot = lu[(k, k)];
            for i in k + 1..n {
                let f = lu[(i, k)] / pivot;
                lu[(i, k)] = f;
                if f != 0.0 {
                    for j in k + 1..n {
                        let v = lu[(k, j)];
                        lu[(i, j)] -= f * v;
                    }
                }
            }
        }
        Ok(Lu { lu, piv })
    }

    /// Solve A x = b.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let n = self.lu.n_rows;
        assert_eq!(b.len(), n);
        // Apply permutation.
        let mut x: Vec<f64> = self.piv.iter().map(|&p| b[p]).collect();
        // Forward substitution (L has unit diagonal).
        for i in 1..n {
            let mut acc = x[i];
            for j in 0..i {
                acc -= self.lu[(i, j)] * x[j];
            }
            x[i] = acc;
        }
        // Back substitution.
        for i in (0..n).rev() {
            let mut acc = x[i];
            for j in i + 1..n {
                acc -= self.lu[(i, j)] * x[j];
            }
            x[i] = acc / self.lu[(i, i)];
        }
        x
    }

    /// Dense inverse via n solves.
    pub fn inverse(&self) -> Mat {
        let n = self.lu.n_rows;
        let mut inv = Mat::zeros(n, n);
        let mut e = vec![0.0; n];
        for j in 0..n {
            e[j] = 1.0;
            let col = self.solve(&e);
            e[j] = 0.0;
            for i in 0..n {
                inv[(i, j)] = col[i];
            }
        }
        inv
    }
}

/// Convenience: invert a matrix.
pub fn inverse(a: &Mat) -> anyhow::Result<Mat> {
    Ok(Lu::factor(a)?.inverse())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_dd(n: usize, seed: u64) -> Mat {
        // Diagonally dominant => well conditioned, like RC conductance mats.
        let mut r = Rng::new(seed);
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            let mut rowsum = 0.0;
            for j in 0..n {
                if i != j {
                    let v = r.range_f64(-1.0, 1.0);
                    m[(i, j)] = v;
                    rowsum += v.abs();
                }
            }
            m[(i, i)] = rowsum + r.range_f64(0.5, 2.0);
        }
        m
    }

    #[test]
    fn lu_solve_recovers_known_solution() {
        for n in [1, 2, 5, 17, 64] {
            let a = random_dd(n, n as u64);
            let mut r = Rng::new(99 + n as u64);
            let x_true: Vec<f64> = (0..n).map(|_| r.range_f64(-3.0, 3.0)).collect();
            let b = a.matvec(&x_true);
            let x = Lu::factor(&a).unwrap().solve(&b);
            for i in 0..n {
                assert!((x[i] - x_true[i]).abs() < 1e-8, "n={n} i={i}");
            }
        }
    }

    #[test]
    fn inverse_times_matrix_is_identity() {
        let a = random_dd(32, 5);
        let inv = inverse(&a).unwrap();
        let prod = inv.matmul(&a);
        assert!(prod.dist(&Mat::identity(32)) < 1e-8);
    }

    #[test]
    fn pivoting_handles_zero_diagonal() {
        let a = Mat::from_rows(&[&[0.0, 1.0], &[1.0, 0.0]]);
        let x = Lu::factor(&a).unwrap().solve(&[2.0, 3.0]);
        assert!((x[0] - 3.0).abs() < 1e-12 && (x[1] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn singular_matrix_rejected() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[2.0, 4.0]]);
        assert!(Lu::factor(&a).is_err());
    }

    #[test]
    fn matmul_matches_manual() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let b = Mat::from_rows(&[&[5.0, 6.0], &[7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }
}
