//! Shared sized worker pool for embarrassingly-parallel jobs.
//!
//! One implementation of the work-pulling / panic-catching pattern used
//! everywhere CHIPSIM fans independent jobs across threads: the scenario
//! [`SweepRunner`](crate::scenario::SweepRunner) (one job per scenario),
//! the fleet dispatcher (one job per replica board per epoch), and the
//! parallel sharded NoC core (`crate::par`, one job per mesh region per
//! synchronization window).  Jobs are indexed `0..n`; workers pull the
//! next index off an atomic counter, so scheduling order never affects
//! results — each slot is written exactly once, and the output vector is
//! in input order.  A panicking job is caught at the job boundary and
//! surfaced as that slot's `Err(message)` instead of unwinding through
//! (and poisoning) the whole pool.
//!
//! # One pool per process
//!
//! Every worker thread marks itself via a thread-local while running
//! jobs.  [`WorkerPool::map_catching`] called *from inside* a worker
//! (e.g. a sharded simulation advanced by a `SweepRunner` job) detects
//! this with [`in_worker`] and runs the jobs inline on the calling
//! thread instead of spawning a nested pool — the outer pool already
//! owns the machine's parallelism, and nesting would oversubscribe it.
//! The same query lets `Simulation::build` fall back to the sequential
//! engine when constructed on a worker thread.

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

thread_local! {
    static IN_WORKER: Cell<bool> = const { Cell::new(false) };
}

/// True while the calling thread is executing a job for a
/// [`WorkerPool`] (directly or via the free [`map_catching`]).  Used to
/// suppress nested pools and per-run parallelism under an outer fan-out.
pub fn in_worker() -> bool {
    IN_WORKER.with(|f| f.get())
}

/// RAII guard marking the current thread as a pool worker.
struct WorkerMark {
    prev: bool,
}

impl WorkerMark {
    fn set() -> Self {
        let prev = IN_WORKER.with(|f| f.replace(true));
        WorkerMark { prev }
    }
}

impl Drop for WorkerMark {
    fn drop(&mut self) {
        let prev = self.prev;
        IN_WORKER.with(|f| f.set(prev));
    }
}

/// A sized worker pool.  Construction is cheap (no threads are kept
/// alive between calls — workers are scoped to each `map_catching`), so
/// the value mostly carries the resolved thread count and gives every
/// fan-out site one shared policy for sizing, thread naming, busy-scope
/// profiling hooks, and nested-call suppression.
#[derive(Debug, Clone, Copy)]
pub struct WorkerPool {
    threads: usize,
}

impl WorkerPool {
    /// A pool of `threads` workers; `0` resolves to the machine's
    /// available parallelism.
    pub fn new(threads: usize) -> Self {
        let threads = if threads > 0 {
            threads
        } else {
            std::thread::available_parallelism().map(|w| w.get()).unwrap_or(1)
        };
        WorkerPool { threads }
    }

    /// The resolved worker count (never 0).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(i)` for every `i in 0..n` across the pool's workers,
    /// returning results in index order.  A panic inside `f(i)` becomes
    /// `Err(panic message)` for slot `i`; the other jobs are
    /// unaffected.  Called from inside another pool job, runs inline on
    /// the calling thread (no nested spawn).
    pub fn map_catching<R, F>(&self, n: usize, f: F) -> Vec<Result<R, String>>
    where
        R: Send,
        F: Fn(usize) -> R + Sync,
    {
        if n == 0 {
            return Vec::new();
        }
        let run_job = |i: usize| -> Result<R, String> {
            // Busy/idle attribution for the parallel-efficiency
            // baseline: one guard per job, no-op unless profiling.
            let _busy = crate::prof::busy_scope();
            match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(i))) {
                Ok(r) => Ok(r),
                Err(payload) => Err(panic_message(payload)),
            }
        };
        if in_worker() || self.threads == 1 || n == 1 {
            // Inline path: already on a worker (nested call) or nothing
            // to parallelize.  Same catching semantics, no threads.
            return (0..n).map(run_job).collect();
        }
        let workers = self.threads.min(n);
        let next = AtomicUsize::new(0);
        let slots: Mutex<Vec<Option<Result<R, String>>>> =
            Mutex::new((0..n).map(|_| None).collect());
        std::thread::scope(|scope| {
            for w in 0..workers {
                // Named threads: OS profilers, flamegraphs, panic
                // messages, and the self-profiler's worker-utilization
                // rows all key on `chipsim-worker-N`.  Naming can only
                // fail on exotic platforms; fall back to an anonymous
                // worker there.
                let work = || {
                    let _mark = WorkerMark::set();
                    loop {
                        let i = next.fetch_add(1, Ordering::SeqCst);
                        if i >= n {
                            break;
                        }
                        let out = run_job(i);
                        slots.lock().expect("pool slot lock")[i] = Some(out);
                    }
                };
                let builder = std::thread::Builder::new().name(format!("chipsim-worker-{w}"));
                if builder.spawn_scoped(scope, work).is_err() {
                    scope.spawn(work);
                }
            }
        });
        slots
            .into_inner()
            .expect("pool slots")
            .into_iter()
            .map(|o| o.expect("every pool job writes its slot"))
            .collect()
    }
}

/// Run `f(i)` for every `i in 0..n` across `threads` workers (`0` =
/// available parallelism), returning results in index order.  A panic
/// inside `f(i)` becomes `Err(panic message)` for slot `i`; the other
/// jobs are unaffected.  Thin wrapper over [`WorkerPool`] kept for the
/// existing call sites.
pub fn map_catching<R, F>(threads: usize, n: usize, f: F) -> Vec<Result<R, String>>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    WorkerPool::new(threads).map_catching(n, f)
}

/// Best-effort extraction of a panic payload's message (`&str` and
/// `String` payloads cover `panic!`, `assert!`, and `unwrap`).
pub fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_come_back_in_input_order() {
        for threads in [1, 4] {
            let out = map_catching(threads, 20, |i| i * i);
            let got: Vec<usize> = out.into_iter().map(|r| r.unwrap()).collect();
            let want: Vec<usize> = (0..20).map(|i| i * i).collect();
            assert_eq!(got, want, "threads={threads}");
        }
    }

    #[test]
    fn a_panicking_job_does_not_poison_the_pool() {
        let out = map_catching(3, 5, |i| {
            if i == 2 {
                panic!("job {i} exploded");
            }
            i
        });
        assert_eq!(out.len(), 5);
        for (i, r) in out.iter().enumerate() {
            if i == 2 {
                assert!(r.as_ref().unwrap_err().contains("exploded"));
            } else {
                assert_eq!(*r.as_ref().unwrap(), i);
            }
        }
    }

    #[test]
    fn empty_input_returns_empty() {
        let out: Vec<Result<usize, String>> = map_catching(4, 0, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn nested_map_catching_runs_inline_without_oversubscription() {
        // An inner pool invoked from a worker job must not spawn its
        // own threads: the inner jobs run on the calling worker, where
        // in_worker() holds.
        let out = WorkerPool::new(4).map_catching(4, |i| {
            assert!(in_worker(), "outer job should run on a marked worker");
            let inner = WorkerPool::new(4).map_catching(3, |j| {
                assert!(in_worker(), "inner job should stay on the same worker");
                i * 10 + j
            });
            inner.into_iter().map(|r| r.unwrap()).sum::<usize>()
        });
        let got: Vec<usize> = out.into_iter().map(|r| r.unwrap()).collect();
        let want: Vec<usize> = (0..4).map(|i| 3 * (i * 10) + 3).collect();
        assert_eq!(got, want);
        assert!(!in_worker(), "mark must not leak to the caller");
    }

    #[test]
    fn zero_threads_resolves_to_available_parallelism() {
        assert!(WorkerPool::new(0).threads() >= 1);
        assert_eq!(WorkerPool::new(3).threads(), 3);
    }
}
