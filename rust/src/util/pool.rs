//! Shared scoped worker pool for embarrassingly-parallel jobs.
//!
//! One implementation of the work-pulling / panic-catching pattern used
//! everywhere CHIPSIM fans independent jobs across threads: the scenario
//! [`SweepRunner`](crate::scenario::SweepRunner) (one job per scenario)
//! and the fleet dispatcher (one job per replica board per epoch).
//! Jobs are indexed `0..n`; workers pull the next index off an atomic
//! counter, so scheduling order never affects results — each slot is
//! written exactly once, and the output vector is in input order.  A
//! panicking job is caught at the job boundary and surfaced as that
//! slot's `Err(message)` instead of unwinding through (and poisoning)
//! the whole pool.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Run `f(i)` for every `i in 0..n` across `threads` workers (`0` =
/// available parallelism), returning results in index order.  A panic
/// inside `f(i)` becomes `Err(panic message)` for slot `i`; the other
/// jobs are unaffected.
pub fn map_catching<R, F>(threads: usize, n: usize, f: F) -> Vec<Result<R, String>>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let workers = if threads > 0 {
        threads
    } else {
        std::thread::available_parallelism().map(|w| w.get()).unwrap_or(1)
    }
    .min(n);
    let next = AtomicUsize::new(0);
    let slots: Mutex<Vec<Option<Result<R, String>>>> =
        Mutex::new((0..n).map(|_| None).collect());
    std::thread::scope(|scope| {
        for w in 0..workers {
            // Named threads: OS profilers, flamegraphs, panic messages,
            // and the self-profiler's worker-utilization rows all key
            // on `chipsim-worker-N`.  Naming can only fail on exotic
            // platforms; fall back to an anonymous worker there.
            let work = || loop {
                let i = next.fetch_add(1, Ordering::SeqCst);
                if i >= n {
                    break;
                }
                // Busy/idle attribution for the parallel-efficiency
                // baseline: one guard per job, no-op unless profiling.
                let _busy = crate::prof::busy_scope();
                let out =
                    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(i))) {
                        Ok(r) => Ok(r),
                        Err(payload) => Err(panic_message(payload)),
                    };
                slots.lock().expect("pool slot lock")[i] = Some(out);
            };
            let builder = std::thread::Builder::new().name(format!("chipsim-worker-{w}"));
            if builder.spawn_scoped(scope, work).is_err() {
                scope.spawn(work);
            }
        }
    });
    slots
        .into_inner()
        .expect("pool slots")
        .into_iter()
        .map(|o| o.expect("every pool job writes its slot"))
        .collect()
}

/// Best-effort extraction of a panic payload's message (`&str` and
/// `String` payloads cover `panic!`, `assert!`, and `unwrap`).
pub fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_come_back_in_input_order() {
        for threads in [1, 4] {
            let out = map_catching(threads, 20, |i| i * i);
            let got: Vec<usize> = out.into_iter().map(|r| r.unwrap()).collect();
            let want: Vec<usize> = (0..20).map(|i| i * i).collect();
            assert_eq!(got, want, "threads={threads}");
        }
    }

    #[test]
    fn a_panicking_job_does_not_poison_the_pool() {
        let out = map_catching(3, 5, |i| {
            if i == 2 {
                panic!("job {i} exploded");
            }
            i
        });
        assert_eq!(out.len(), 5);
        for (i, r) in out.iter().enumerate() {
            if i == 2 {
                assert!(r.as_ref().unwrap_err().contains("exploded"));
            } else {
                assert_eq!(*r.as_ref().unwrap(), i);
            }
        }
    }

    #[test]
    fn empty_input_returns_empty() {
        let out: Vec<Result<usize, String>> = map_catching(4, 0, |i| i);
        assert!(out.is_empty());
    }
}
