//! Minimal JSON parser + emitter (serde_json replacement).
//!
//! Used for: the AOT `artifacts/manifest.json`, hardware/workload config
//! files, and machine-readable experiment output.  Supports the full JSON
//! grammar except surrogate-pair escapes beyond the BMP (sufficient for
//! our ASCII configs); numbers are f64.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

/// Parse / access error.
#[derive(Debug, thiserror::Error)]
pub enum JsonError {
    #[error("json parse error at byte {pos}: {msg}")]
    Parse { pos: usize, msg: String },
    #[error("json access error: {0}")]
    Access(String),
}

impl Value {
    // ---------------------------------------------------------------- access

    pub fn as_f64(&self) -> Result<f64, JsonError> {
        match self {
            Value::Num(x) => Ok(*x),
            other => Err(JsonError::Access(format!("expected number, got {other:?}"))),
        }
    }

    pub fn as_u64(&self) -> Result<u64, JsonError> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            return Err(JsonError::Access(format!("expected unsigned integer, got {x}")));
        }
        Ok(x as u64)
    }

    pub fn as_usize(&self) -> Result<usize, JsonError> {
        Ok(self.as_u64()? as usize)
    }

    pub fn as_bool(&self) -> Result<bool, JsonError> {
        match self {
            Value::Bool(b) => Ok(*b),
            other => Err(JsonError::Access(format!("expected bool, got {other:?}"))),
        }
    }

    pub fn as_str(&self) -> Result<&str, JsonError> {
        match self {
            Value::Str(s) => Ok(s),
            other => Err(JsonError::Access(format!("expected string, got {other:?}"))),
        }
    }

    pub fn as_arr(&self) -> Result<&[Value], JsonError> {
        match self {
            Value::Arr(a) => Ok(a),
            other => Err(JsonError::Access(format!("expected array, got {other:?}"))),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Value>, JsonError> {
        match self {
            Value::Obj(o) => Ok(o),
            other => Err(JsonError::Access(format!("expected object, got {other:?}"))),
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Result<&Value, JsonError> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| JsonError::Access(format!("missing key '{key}'")))
    }

    /// Optional object field lookup.
    pub fn opt(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(o) => o.get(key),
            _ => None,
        }
    }

    // ----------------------------------------------------------- constructors

    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Value {
        Value::Arr(xs.iter().map(|&x| Value::Num(x)).collect())
    }

    pub fn from_str_slice(xs: &[&str]) -> Value {
        Value::Arr(xs.iter().map(|s| Value::Str(s.to_string())).collect())
    }
}

impl From<f64> for Value {
    fn from(x: f64) -> Self {
        Value::Num(x)
    }
}
impl From<u64> for Value {
    fn from(x: u64) -> Self {
        Value::Num(x as f64)
    }
}
impl From<usize> for Value {
    fn from(x: usize) -> Self {
        Value::Num(x as f64)
    }
}
impl From<bool> for Value {
    fn from(x: bool) -> Self {
        Value::Bool(x)
    }
}
impl From<&str> for Value {
    fn from(x: &str) -> Self {
        Value::Str(x.to_string())
    }
}
impl From<String> for Value {
    fn from(x: String) -> Self {
        Value::Str(x)
    }
}

// ------------------------------------------------------------------- parsing

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: impl Into<String>) -> Result<T, JsonError> {
        Err(JsonError::Parse { pos: self.pos, msg: msg.into() })
    }

    fn skip_ws(&mut self) {
        while self.pos < self.b.len() && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            self.err(format!("expected '{}'", c as char))
        }
    }

    fn value(&mut self) -> Result<Value, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => self.err(format!("unexpected byte '{}'", c as char)),
            None => self.err("unexpected end of input"),
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            self.err(format!("expected '{word}'"))
        }
    }

    fn object(&mut self) -> Result<Value, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(map));
                }
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }

    fn array(&mut self) -> Result<Value, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(items));
                }
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return self.err("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000C}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return self.err("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                .map_err(|_| JsonError::Parse {
                                    pos: self.pos,
                                    msg: "invalid \\u escape".into(),
                                })?;
                            let code = u32::from_str_radix(hex, 16).map_err(|_| {
                                JsonError::Parse { pos: self.pos, msg: "invalid \\u hex".into() }
                            })?;
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return self.err("invalid escape"),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Copy a run of unescaped bytes (UTF-8 passthrough).
                    let start = self.pos;
                    while self.pos < self.b.len()
                        && self.b[self.pos] != b'"'
                        && self.b[self.pos] != b'\\'
                    {
                        self.pos += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.pos]).map_err(|_| {
                            JsonError::Parse { pos: start, msg: "invalid utf-8".into() }
                        })?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|e| JsonError::Parse { pos: start, msg: format!("bad number: {e}") })
    }
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Value, JsonError> {
    let mut p = Parser { b: text.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.b.len() {
        return p.err("trailing garbage");
    }
    Ok(v)
}

/// Parse the JSON file at `path`.
pub fn parse_file(path: impl AsRef<std::path::Path>) -> anyhow::Result<Value> {
    let text = std::fs::read_to_string(path.as_ref())
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.as_ref().display()))?;
    Ok(parse(&text)?)
}

// ------------------------------------------------------------------ emitting

fn escape_into(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

fn write_value(v: &Value, indent: usize, pretty: bool, out: &mut String) {
    let (nl, pad, pad_in): (&str, String, String) = if pretty {
        ("\n", "  ".repeat(indent), "  ".repeat(indent + 1))
    } else {
        ("", String::new(), String::new())
    };
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(x) => {
            if x.fract() == 0.0 && x.abs() < 9e15 {
                out.push_str(&format!("{}", *x as i64));
            } else {
                out.push_str(&format!("{x}"));
            }
        }
        Value::Str(s) => escape_into(s, out),
        Value::Arr(items) => {
            if items.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push_str(nl);
                out.push_str(&pad_in);
                write_value(item, indent + 1, pretty, out);
            }
            out.push_str(nl);
            out.push_str(&pad);
            out.push(']');
        }
        Value::Obj(map) => {
            if map.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push_str(nl);
                out.push_str(&pad_in);
                escape_into(k, out);
                out.push(':');
                if pretty {
                    out.push(' ');
                }
                write_value(val, indent + 1, pretty, out);
            }
            out.push_str(nl);
            out.push_str(&pad);
            out.push('}');
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        write_value(self, 0, f.alternate(), &mut s);
        f.write_str(&s)
    }
}

/// Compact serialization.
pub fn to_string(v: &Value) -> String {
    format!("{v}")
}

/// Pretty (2-space indented) serialization.
pub fn to_string_pretty(v: &Value) -> String {
    format!("{v:#}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("-2.5e2").unwrap(), Value::Num(-250.0));
        assert_eq!(parse("\"hi\\n\"").unwrap(), Value::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        assert!(!v.get("a").unwrap().as_arr().unwrap()[2]
            .get("b")
            .unwrap()
            .as_bool()
            .unwrap());
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(parse(r#""A""#).unwrap(), Value::Str("A".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"chipsim","n":100,"ok":true,"xs":[1,2.5,-3],"nested":{"z":null}}"#;
        let v = parse(src).unwrap();
        let emitted = to_string(&v);
        assert_eq!(parse(&emitted).unwrap(), v);
        let pretty = to_string_pretty(&v);
        assert_eq!(parse(&pretty).unwrap(), v);
    }

    #[test]
    fn integers_emit_without_decimal_point() {
        assert_eq!(to_string(&Value::Num(42.0)), "42");
        assert_eq!(to_string(&Value::Num(0.5)), "0.5");
    }
}
